//! Vector datasets: synthetic generators, the paper's binary file
//! format, and bit-packed Sorenson vectors.
//!
//! Paper §5 defines two synthetic problem types, both reproduced here:
//! 1. **RandomGrid** — "each vector entry is set to a randomized value".
//!    We snap values to the k/64 grid so every partial sum is exact in
//!    f32 and f64, which is what makes results bit-identical across all
//!    code versions and parallel decompositions (the checksum contract).
//! 2. **Verifiable** — "randomized placement of entries specifically
//!    chosen so that the correctness of every result value can be
//!    verified analytically": each vector is an indicator of a single
//!    feature bucket, so c2 ∈ {0, 1} and c3 ∈ {0, 1/2, 1} in closed form
//!    (see [`SyntheticKind::Verifiable`] docs).
//! 3. **PhewasLike** — the realistic §6.8 stand-in: sparse, non-negative
//!    grid-valued profiles with n_f = 385-style shapes.
//!
//! Every entry is a pure function of (seed, global vector id, feature) —
//! node-assignment independent, per the bit-for-bit requirement.

pub mod bits;
pub mod block;
pub mod geno;
pub mod io;
pub mod oocstore;

use anyhow::bail;

use crate::util::prng::Stream;
use crate::util::Scalar;

/// Synthetic dataset families (paper §5 + §6.8).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SyntheticKind {
    /// Dense values on the k/64 grid, k ∈ [1, 64] (strictly positive so
    /// denominators never vanish).
    RandomGrid,
    /// Single-bucket indicator vectors with analytically-known metrics:
    /// vector i holds value 1 at feature `bucket(i)` and 0 elsewhere.
    /// Then n2(i,j) = [bucket(i) = bucket(j)], d2 = 2, and
    /// c2 ∈ {0, 1}; similarly c3(i,j,k) = 1 if all three buckets match,
    /// 1/2 if exactly two match, 0 otherwise.
    Verifiable,
    /// Sparse PheWAS-profile stand-in: ~10% density, grid-valued.
    PhewasLike,
    /// Allele-count vectors for the CCC metric (companion paper):
    /// entries uniform over {0, 1, 2} (2-bit genotype encodings),
    /// exact in both precisions. A fallback entry guarantees each
    /// vector is nonzero.
    Alleles,
}

impl SyntheticKind {
    /// Every registered generator, in CLI-help order.
    pub const ALL: [SyntheticKind; 4] = [
        SyntheticKind::RandomGrid,
        SyntheticKind::Verifiable,
        SyntheticKind::PhewasLike,
        SyntheticKind::Alleles,
    ];

    /// The name [`SyntheticKind::parse`] accepts (and `run.meta`-style
    /// output uses).
    pub fn name(self) -> &'static str {
        match self {
            SyntheticKind::RandomGrid => "grid",
            SyntheticKind::Verifiable => "verifiable",
            SyntheticKind::PhewasLike => "phewas",
            SyntheticKind::Alleles => "alleles",
        }
    }

    /// Parse a generator name — the single source of truth for the
    /// `--synthetic` / `input.synthetic` vocabulary (previously copied
    /// in `cmd_run`, `cmd_gen_data`, and the TOML lowering, which is
    /// exactly how vocabularies drift apart).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        for kind in Self::ALL {
            if s == kind.name() {
                return Ok(kind);
            }
        }
        let valid: Vec<&str> = Self::ALL.iter().map(|k| k.name()).collect();
        bail!(
            "unknown synthetic kind {s:?} (want one of: {})",
            valid.join("|")
        )
    }
}

/// A set of n_v vectors of n_f features, stored column-major
/// (vector-contiguous — the paper's layout; each vector is one column).
#[derive(Debug, Clone)]
pub struct VectorSet<T: Scalar> {
    pub nf: usize,
    pub nv: usize,
    /// First global vector id in this set (block offset within the
    /// campaign-wide vector numbering).
    pub first_id: usize,
    data: Vec<T>,
}

impl<T: Scalar> VectorSet<T> {
    pub fn zeros(nf: usize, nv: usize) -> Self {
        VectorSet {
            nf,
            nv,
            first_id: 0,
            data: vec![T::ZERO; nf * nv],
        }
    }

    /// Generate the block of global vectors [first_id, first_id + nv).
    pub fn generate(kind: SyntheticKind, seed: u64, nf: usize, nv: usize, first_id: usize) -> Self {
        let mut set = VectorSet::zeros(nf, nv);
        set.first_id = first_id;
        for local in 0..nv {
            let gid = (first_id + local) as u64;
            let mut s = Stream::for_vector(seed, gid);
            let col = set.col_mut(local);
            match kind {
                SyntheticKind::RandomGrid => {
                    for x in col.iter_mut() {
                        // k/64 with k in [1, 64]: exact sums, no zeros.
                        *x = T::from_f64((s.below(64) + 1) as f64 / 64.0);
                    }
                }
                SyntheticKind::Verifiable => {
                    let bucket = s.below(nf as u64) as usize;
                    col[bucket] = T::ONE;
                }
                SyntheticKind::PhewasLike => {
                    for x in col.iter_mut() {
                        if s.next_f64() < 0.1 {
                            *x = T::from_f64((s.below(64) + 1) as f64 / 64.0);
                        }
                    }
                    // Guarantee at least one nonzero so d2 > 0.
                    let fallback = s.below(nf as u64) as usize;
                    if col.iter().all(|x| x.to_f64() == 0.0) {
                        col[fallback] = T::from_f64(1.0 / 64.0);
                    }
                }
                SyntheticKind::Alleles => {
                    for x in col.iter_mut() {
                        *x = T::from_f64(s.below(3) as f64);
                    }
                    // Guarantee at least one nonzero so denominators of
                    // sum-based metrics never vanish.
                    let fallback = s.below(nf as u64) as usize;
                    if col.iter().all(|x| x.to_f64() == 0.0) {
                        col[fallback] = T::ONE;
                    }
                }
            }
        }
        set
    }

    /// The feature bucket of a Verifiable vector (for analytic checks).
    pub fn verifiable_bucket(seed: u64, nf: usize, gid: usize) -> usize {
        Stream::for_vector(seed, gid as u64).below(nf as u64) as usize
    }

    #[inline]
    pub fn col(&self, v: usize) -> &[T] {
        &self.data[v * self.nf..(v + 1) * self.nf]
    }

    #[inline]
    pub fn col_mut(&mut self, v: usize) -> &mut [T] {
        &mut self.data[v * self.nf..(v + 1) * self.nf]
    }

    pub fn raw(&self) -> &[T] {
        &self.data
    }

    pub fn raw_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Column sums Σ_q v_q — the denominator ingredients, computed on
    /// the coordinator ("CPU") side exactly as in the paper (§3.1).
    pub fn col_sums(&self) -> Vec<f64> {
        (0..self.nv)
            .map(|v| {
                let mut acc = T::ZERO;
                for &x in self.col(v) {
                    acc += x;
                }
                acc.to_f64()
            })
            .collect()
    }

    /// Restrict to a feature subrange [f0, f0 + len) — the n_pf
    /// (vector-elements) decomposition axis (§4.1).
    pub fn feature_slice(&self, f0: usize, len: usize) -> VectorSet<T> {
        assert!(f0 + len <= self.nf);
        let mut out = VectorSet::zeros(len, self.nv);
        out.first_id = self.first_id;
        for v in 0..self.nv {
            out.col_mut(v).copy_from_slice(&self.col(v)[f0..f0 + len]);
        }
        out
    }

    /// Row-major [nf, nv] buffer zero-padded to (nf_pad, nv_pad) — the
    /// layout the AOT artifacts expect (jax arrays are row-major).
    /// Zero padding is exact for the min-product over non-negative data.
    pub fn to_rowmajor_padded(&self, nf_pad: usize, nv_pad: usize) -> Vec<T> {
        assert!(nf_pad >= self.nf && nv_pad >= self.nv);
        let mut out = vec![T::ZERO; nf_pad * nv_pad];
        for v in 0..self.nv {
            let col = self.col(v);
            for q in 0..self.nf {
                out[q * nv_pad + v] = col[q];
            }
        }
        out
    }

    /// Select a subset of columns into a new (dense) set.
    pub fn select_cols(&self, cols: &[usize]) -> VectorSet<T> {
        let mut out = VectorSet::zeros(self.nf, cols.len());
        out.first_id = self.first_id;
        for (local, &c) in cols.iter().enumerate() {
            out.col_mut(local).copy_from_slice(self.col(c));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_kind_parse_roundtrip() {
        for kind in SyntheticKind::ALL {
            assert_eq!(SyntheticKind::parse(kind.name()).unwrap(), kind);
        }
        let err = SyntheticKind::parse("gridd").unwrap_err().to_string();
        // The error must teach the full vocabulary.
        for kind in SyntheticKind::ALL {
            assert!(err.contains(kind.name()), "{err}");
        }
    }

    #[test]
    fn generation_is_decomposition_independent() {
        // Generating [0, 8) at once must equal generating [0,4) and [4,8)
        // separately — the bit-for-bit requirement.
        let all: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 7, 33, 8, 0);
        let lo: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 7, 33, 4, 0);
        let hi: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 7, 33, 4, 4);
        for v in 0..4 {
            assert_eq!(all.col(v), lo.col(v));
            assert_eq!(all.col(v + 4), hi.col(v));
        }
    }

    #[test]
    fn random_grid_values_on_grid_and_positive() {
        let s: VectorSet<f32> = VectorSet::generate(SyntheticKind::RandomGrid, 1, 64, 16, 0);
        for v in 0..16 {
            for &x in s.col(v) {
                let k = (x as f64 * 64.0).round();
                assert!((1.0..=64.0).contains(&k));
                assert_eq!(x as f64, k / 64.0);
            }
        }
    }

    #[test]
    fn verifiable_has_single_unit_entry() {
        let s: VectorSet<f64> = VectorSet::generate(SyntheticKind::Verifiable, 3, 50, 20, 0);
        for v in 0..20 {
            let col = s.col(v);
            let nnz = col.iter().filter(|&&x| x != 0.0).count();
            assert_eq!(nnz, 1);
            let bucket = VectorSet::<f64>::verifiable_bucket(3, 50, v);
            assert_eq!(col[bucket], 1.0);
        }
    }

    #[test]
    fn verifiable_metric_values_are_analytic() {
        let seed = 11;
        let (nf, nv) = (10, 30); // small nf forces bucket collisions
        let s: VectorSet<f64> = VectorSet::generate(SyntheticKind::Verifiable, seed, nf, nv, 0);
        for i in 0..nv {
            for j in (i + 1)..nv {
                let c = crate::metrics::czekanowski2(s.col(i), s.col(j));
                let bi = VectorSet::<f64>::verifiable_bucket(seed, nf, i);
                let bj = VectorSet::<f64>::verifiable_bucket(seed, nf, j);
                let expect = if bi == bj { 1.0 } else { 0.0 };
                assert_eq!(c, expect, "pair ({i}, {j})");
            }
        }
    }

    #[test]
    fn verifiable_c3_three_levels() {
        let seed = 13;
        let (nf, nv) = (4, 24);
        let s: VectorSet<f64> = VectorSet::generate(SyntheticKind::Verifiable, seed, nf, nv, 0);
        let b: Vec<usize> = (0..nv)
            .map(|g| VectorSet::<f64>::verifiable_bucket(seed, nf, g))
            .collect();
        let mut seen = [false; 3];
        for (i, j, k) in crate::metrics::indexing::triples(nv) {
            let c = crate::metrics::czekanowski3(s.col(i), s.col(j), s.col(k));
            let matches =
                (b[i] == b[j]) as usize + (b[i] == b[k]) as usize + (b[j] == b[k]) as usize;
            let expect = match matches {
                3 => 1.0,
                1 => 0.5,
                0 => 0.0,
                _ => unreachable!("two equalities imply the third"),
            };
            assert_eq!(c, expect, "triple ({i},{j},{k})");
            seen[matches.min(2)] = true;
        }
        assert!(seen.iter().all(|&x| x), "want all three analytic levels");
    }

    #[test]
    fn alleles_values_in_count_domain() {
        let s: VectorSet<f64> = VectorSet::generate(SyntheticKind::Alleles, 3, 80, 20, 0);
        let mut seen = [false; 3];
        for v in 0..20 {
            for &x in s.col(v) {
                assert!(x == 0.0 || x == 1.0 || x == 2.0, "x={x}");
                seen[x as usize] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "want all of {{0,1,2}} to occur");
        assert!(s.col_sums().iter().all(|&d| d > 0.0));
    }

    #[test]
    fn alleles_generation_is_decomposition_independent() {
        let all: VectorSet<f64> = VectorSet::generate(SyntheticKind::Alleles, 5, 40, 8, 0);
        let hi: VectorSet<f64> = VectorSet::generate(SyntheticKind::Alleles, 5, 40, 4, 4);
        for v in 0..4 {
            assert_eq!(all.col(v + 4), hi.col(v));
        }
    }

    #[test]
    fn phewas_like_sparse_and_nonzero() {
        let s: VectorSet<f64> = VectorSet::generate(SyntheticKind::PhewasLike, 5, 385, 50, 0);
        let sums = s.col_sums();
        assert!(sums.iter().all(|&x| x > 0.0));
        let nnz: usize = (0..50)
            .map(|v| s.col(v).iter().filter(|&&x| x != 0.0).count())
            .sum();
        let density = nnz as f64 / (385.0 * 50.0);
        assert!((0.05..0.2).contains(&density), "density={density}");
    }

    #[test]
    fn rowmajor_padding_layout() {
        let mut s: VectorSet<f64> = VectorSet::zeros(2, 2);
        s.col_mut(0).copy_from_slice(&[1.0, 2.0]);
        s.col_mut(1).copy_from_slice(&[3.0, 4.0]);
        let rm = s.to_rowmajor_padded(3, 3);
        // row-major [nf_pad=3, nv_pad=3]: element (q, v) at q*3 + v.
        assert_eq!(rm, vec![1.0, 3.0, 0.0, 2.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn feature_slice_partitions_sums() {
        let s: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 2, 40, 6, 0);
        let a = s.feature_slice(0, 25);
        let b = s.feature_slice(25, 15);
        let total = s.col_sums();
        let pa = a.col_sums();
        let pb = b.col_sums();
        for v in 0..6 {
            assert!((total[v] - (pa[v] + pb[v])).abs() < 1e-12);
        }
    }

    #[test]
    fn select_cols_copies() {
        let s: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 2, 16, 6, 0);
        let sub = s.select_cols(&[1, 4]);
        assert_eq!(sub.nv, 2);
        assert_eq!(sub.col(0), s.col(1));
        assert_eq!(sub.col(1), s.col(4));
    }
}
