//! Metric output writing (paper §6.8): one binary file per node, each
//! metric stored as a single unsigned byte (~2.5 significant figures),
//! no explicit indexing (offsets are formulaic — `metrics::indexing`),
//! optional thresholding.

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Quantize a metric value in [0, 1.5] to one byte. c2 ∈ [0, 1] and
/// c3 ∈ [0, 1] in practice (c3 ≤ 1 for the paper's data); we scale by
/// 1/255 over [0, 1] and saturate, matching "roughly 2-1/2 significant
/// figures" (§6.8).
#[inline]
pub fn quantize(value: f64) -> u8 {
    (value.clamp(0.0, 1.0) * 255.0).round() as u8
}

/// Inverse of [`quantize`] (midpoint reconstruction).
#[inline]
pub fn dequantize(b: u8) -> f64 {
    b as f64 / 255.0
}

/// Streaming per-node metrics writer.
pub struct NodeWriter {
    path: PathBuf,
    w: BufWriter<File>,
    /// Optional threshold: values below it are dropped (with their
    /// offsets written alongside, since thresholding breaks formulaic
    /// indexing — §6.8 writes "all metrics … with no thresholding";
    /// thresholded mode writes (offset u64, byte) records instead).
    threshold: Option<f64>,
    pub written: u64,
    pub dropped: u64,
}

impl NodeWriter {
    /// `rank` names the file: `<dir>/metrics_<rank>.bin`.
    pub fn create(dir: &Path, rank: usize, threshold: Option<f64>) -> Result<Self> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create output dir {}", dir.display()))?;
        let path = dir.join(format!("metrics_{rank}.bin"));
        let f = File::create(&path).with_context(|| format!("create {}", path.display()))?;
        Ok(NodeWriter {
            path,
            w: BufWriter::new(f),
            threshold,
            written: 0,
            dropped: 0,
        })
    }

    /// Write one metric at its formulaic offset.
    pub fn write(&mut self, offset: u64, value: f64) -> Result<()> {
        match self.threshold {
            None => {
                self.w.write_all(&[quantize(value)])?;
                self.written += 1;
            }
            Some(t) if value >= t => {
                self.w.write_all(&offset.to_le_bytes())?;
                self.w.write_all(&[quantize(value)])?;
                self.written += 1;
            }
            Some(_) => self.dropped += 1,
        }
        Ok(())
    }

    pub fn finish(mut self) -> Result<(PathBuf, u64)> {
        self.w.flush()?;
        Ok((self.path, self.written))
    }
}

/// Read back a dense (unthresholded) node file.
pub fn read_dense(path: &Path) -> Result<Vec<u8>> {
    let mut buf = Vec::new();
    File::open(path)
        .with_context(|| format!("open {}", path.display()))?
        .read_to_end(&mut buf)?;
    Ok(buf)
}

/// Read back a thresholded node file: (offset, value-byte) records.
pub fn read_thresholded(path: &Path) -> Result<Vec<(u64, u8)>> {
    let raw = read_dense(path)?;
    anyhow::ensure!(raw.len() % 9 == 0, "corrupt thresholded file");
    Ok(raw
        .chunks_exact(9)
        .map(|c| {
            let mut off = [0u8; 8];
            off.copy_from_slice(&c[..8]);
            (u64::from_le_bytes(off), c[8])
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> PathBuf {
        let d = std::env::temp_dir().join(format!("comet-out-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn quantize_roundtrip_precision() {
        for v in [0.0, 0.1, 0.5, 0.77, 1.0] {
            let q = dequantize(quantize(v));
            assert!((q - v).abs() <= 0.5 / 255.0 + 1e-12, "{v} -> {q}");
        }
        assert_eq!(quantize(-0.5), 0);
        assert_eq!(quantize(2.0), 255);
    }

    #[test]
    fn dense_write_read() {
        let dir = tmpdir();
        let mut w = NodeWriter::create(&dir, 3, None).unwrap();
        w.write(0, 0.5).unwrap();
        w.write(1, 1.0).unwrap();
        let (path, n) = w.finish().unwrap();
        assert_eq!(n, 2);
        let back = read_dense(&path).unwrap();
        assert_eq!(back, vec![quantize(0.5), quantize(1.0)]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn thresholded_write_read() {
        let dir = tmpdir();
        let mut w = NodeWriter::create(&dir, 4, Some(0.5)).unwrap();
        w.write(10, 0.9).unwrap();
        w.write(11, 0.1).unwrap(); // dropped
        w.write(12, 0.6).unwrap();
        assert_eq!(w.dropped, 1);
        let (path, n) = w.finish().unwrap();
        assert_eq!(n, 2);
        let recs = read_thresholded(&path).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].0, 10);
        assert_eq!(recs[1], (12, quantize(0.6)));
        std::fs::remove_file(path).ok();
    }
}
