//! Metric output writing (paper §6.8): one binary file per node, each
//! metric stored as a single unsigned byte (~2.5 significant figures),
//! no explicit indexing (offsets are formulaic — `metrics::indexing`),
//! optional thresholding.
//!
//! The per-node byte files stay headerless, so a run also writes one
//! `run.meta` sidecar tagging the directory with the metric family
//! that produced it (plus the shape needed to interpret the offsets).
//!
//! File output is one implementation of the streaming [`sink`] API
//! ([`sink::FileSink`] wraps [`NodeWriter`]); the coordinator's node
//! programs only ever talk to a [`sink::ResultSink`]. The [`wire`]
//! module gives tiles a cross-process form: versioned binary frames
//! ([`wire::Frame`]) streamed by [`wire::SocketSink`] for `comet
//! serve`.

pub mod sink;
pub mod wire;

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::RunConfig;
use crate::coordinator::RunStats;

/// Quantize a metric value in [0, 1.5] to one byte. c2 ∈ [0, 1] and
/// c3 ∈ [0, 1] in practice (c3 ≤ 1 for the paper's data); we scale by
/// 1/255 over [0, 1] and saturate, matching "roughly 2-1/2 significant
/// figures" (§6.8).
#[inline]
pub fn quantize(value: f64) -> u8 {
    (value.clamp(0.0, 1.0) * 255.0).round() as u8
}

/// Inverse of [`quantize`] (midpoint reconstruction).
#[inline]
pub fn dequantize(b: u8) -> f64 {
    b as f64 / 255.0
}

/// Streaming per-node metrics writer.
pub struct NodeWriter {
    path: PathBuf,
    w: BufWriter<File>,
    /// Optional threshold: values below it are dropped (with their
    /// offsets written alongside, since thresholding breaks formulaic
    /// indexing — §6.8 writes "all metrics … with no thresholding";
    /// thresholded mode writes (offset u64, byte) records instead).
    threshold: Option<f64>,
    pub written: u64,
    pub dropped: u64,
}

impl NodeWriter {
    /// `rank` names the file: `<dir>/metrics_<rank>.bin`.
    pub fn create(dir: &Path, rank: usize, threshold: Option<f64>) -> Result<Self> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create output dir {}", dir.display()))?;
        let path = dir.join(format!("metrics_{rank}.bin"));
        let f = File::create(&path).with_context(|| format!("create {}", path.display()))?;
        Ok(NodeWriter {
            path,
            w: BufWriter::new(f),
            threshold,
            written: 0,
            dropped: 0,
        })
    }

    /// Write one metric at its formulaic offset.
    pub fn write(&mut self, offset: u64, value: f64) -> Result<()> {
        match self.threshold {
            None => {
                self.w.write_all(&[quantize(value)])?;
                self.written += 1;
            }
            Some(t) if value >= t => {
                self.w.write_all(&offset.to_le_bytes())?;
                self.w.write_all(&[quantize(value)])?;
                self.written += 1;
            }
            Some(_) => self.dropped += 1,
        }
        Ok(())
    }

    pub fn finish(mut self) -> Result<(PathBuf, u64)> {
        self.w.flush()?;
        Ok((self.path, self.written))
    }
}

/// Write the `run.meta` sidecar for an output directory: the §6.8
/// metric files are raw byte streams, so this records which metric
/// family produced them and the shape needed to decode the formulaic
/// offsets. The format is the same TOML subset `config::toml` parses,
/// so [`read_run_meta`] round-trips it.
/// `repr` is the block representation the run's *metric instance*
/// actually used (`Metric::preferred_repr`) — passed explicitly rather
/// than derived from `cfg.metric` so an instance overriding the
/// registry default can never write a lying sidecar. `diag_kernel` is
/// likewise the *backend instance*'s report ("triangular" | "full") of
/// which kernel serviced diagonal blocks.
pub fn write_run_meta(
    dir: &Path,
    cfg: &RunConfig,
    repr: crate::vecdata::block::Repr,
    diag_kernel: &str,
    stats: &RunStats,
) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("create output dir {}", dir.display()))?;
    let path = dir.join("run.meta");
    let mut text = String::new();
    text.push_str("# CoMet-RS run metadata (decodes the metrics_<rank>.bin files)\n");
    text.push_str("[run]\n");
    text.push_str(&format!("metric = \"{}\"\n", cfg.metric.name()));
    text.push_str(&format!("repr = \"{}\"\n", repr.name()));
    text.push_str(&format!("num_way = {}\n", cfg.num_way));
    text.push_str(&format!("nv = {}\n", cfg.nv));
    text.push_str(&format!("nf = {}\n", cfg.nf));
    text.push_str(&format!("precision = \"{}\"\n", cfg.precision.tag()));
    text.push_str(&format!("backend = \"{}\"\n", cfg.backend.name()));
    text.push_str(&format!("threads = {}\n", cfg.threads));
    text.push_str(&format!("kernel = \"{diag_kernel}\"\n"));
    text.push_str(&format!("nodes = {}\n", cfg.grid.np()));
    text.push_str(&format!("metrics = {}\n", stats.metrics));
    if let Some(t) = cfg.output_threshold {
        text.push_str(&format!("threshold = {t}\n"));
    }
    std::fs::write(&path, text).with_context(|| format!("write {}", path.display()))?;
    Ok(path)
}

/// Parse an output directory's `run.meta` sidecar.
pub fn read_run_meta(dir: &Path) -> Result<crate::config::toml::Doc> {
    let path = dir.join("run.meta");
    let text =
        std::fs::read_to_string(&path).with_context(|| format!("read {}", path.display()))?;
    crate::config::toml::parse(&text)
}

/// Read back a dense (unthresholded) node file.
pub fn read_dense(path: &Path) -> Result<Vec<u8>> {
    let mut buf = Vec::new();
    File::open(path)
        .with_context(|| format!("open {}", path.display()))?
        .read_to_end(&mut buf)?;
    Ok(buf)
}

/// Read back a thresholded node file: (offset, value-byte) records.
pub fn read_thresholded(path: &Path) -> Result<Vec<(u64, u8)>> {
    let raw = read_dense(path)?;
    anyhow::ensure!(raw.len() % 9 == 0, "corrupt thresholded file");
    Ok(raw
        .chunks_exact(9)
        .map(|c| {
            let mut off = [0u8; 8];
            off.copy_from_slice(&c[..8]);
            (u64::from_le_bytes(off), c[8])
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> PathBuf {
        let d = std::env::temp_dir().join(format!("comet-out-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn quantize_roundtrip_precision() {
        for v in [0.0, 0.1, 0.5, 0.77, 1.0] {
            let q = dequantize(quantize(v));
            assert!((q - v).abs() <= 0.5 / 255.0 + 1e-12, "{v} -> {q}");
        }
        assert_eq!(quantize(-0.5), 0);
        assert_eq!(quantize(2.0), 255);
    }

    #[test]
    fn dense_write_read() {
        let dir = tmpdir();
        let mut w = NodeWriter::create(&dir, 3, None).unwrap();
        w.write(0, 0.5).unwrap();
        w.write(1, 1.0).unwrap();
        let (path, n) = w.finish().unwrap();
        assert_eq!(n, 2);
        let back = read_dense(&path).unwrap();
        assert_eq!(back, vec![quantize(0.5), quantize(1.0)]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn run_meta_roundtrip() {
        let dir = tmpdir().join("meta");
        let cfg = RunConfig {
            metric: crate::metrics::MetricId::Ccc,
            num_way: 2,
            nv: 40,
            nf: 64,
            threads: 4,
            output_threshold: Some(0.25),
            ..Default::default()
        };
        let stats = RunStats { metrics: 780, ..Default::default() };
        write_run_meta(&dir, &cfg, cfg.metric.preferred_repr(), "triangular", &stats).unwrap();
        let doc = read_run_meta(&dir).unwrap();
        assert_eq!(doc.get("run", "metric").unwrap().as_str().unwrap(), "ccc");
        assert_eq!(doc.get("run", "repr").unwrap().as_str().unwrap(), "float");
        assert_eq!(doc.get("run", "threads").unwrap().as_int().unwrap(), 4);
        assert_eq!(doc.get("run", "kernel").unwrap().as_str().unwrap(), "triangular");
        assert_eq!(doc.get("run", "nv").unwrap().as_int().unwrap(), 40);
        assert_eq!(doc.get("run", "metrics").unwrap().as_int().unwrap(), 780);
        assert_eq!(doc.get("run", "threshold").unwrap().as_float().unwrap(), 0.25);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn thresholded_write_read() {
        let dir = tmpdir();
        let mut w = NodeWriter::create(&dir, 4, Some(0.5)).unwrap();
        w.write(10, 0.9).unwrap();
        w.write(11, 0.1).unwrap(); // dropped
        w.write(12, 0.6).unwrap();
        assert_eq!(w.dropped, 1);
        let (path, n) = w.finish().unwrap();
        assert_eq!(n, 2);
        let recs = read_thresholded(&path).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].0, 10);
        assert_eq!(recs[1], (12, quantize(0.6)));
        std::fs::remove_file(path).ok();
    }
}
