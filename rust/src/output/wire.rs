//! Versioned binary wire format for streaming results cross-process.
//!
//! `comet serve` ships finished [`Tile`]s to clients as length-prefixed
//! **frames** over any byte stream (Unix socket, pipe, stdin/stdout).
//! The format is deliberately dumb — little-endian, fixed-width, no
//! compression — so a client in any language can decode it with a
//! dozen lines, and decoding is total: malformed input of every kind
//! (truncation, bad version, unknown kind, trailing garbage, absurd
//! length prefixes) returns an error, never panics and never
//! over-allocates.
//!
//! ## Frame layout
//!
//! ```text
//! frame   := len:u32le payload            (len = payload byte count)
//! payload := version:u8 kind:u8 body
//! version := 0x01                         (WIRE_VERSION)
//! kind    := 0x01 pairs | 0x02 triples | 0x03 done | 0x04 error
//! pairs   := metric:u8 count:u32le { i:u32le j:u32le bits:u64le }*
//! triples := metric:u8 count:u32le { i:u32le j:u32le k:u32le bits:u64le }*
//! done    := metrics:u64le len:u32le checksum-digest:utf8
//! error   := len:u32le message:utf8
//! ```
//!
//! Values travel as raw `f64::to_bits` words, so a decoded tile is
//! **bit-identical** to the tile the node program emitted — the serving
//! acceptance contract (`tests/serve_concurrency.rs`) diffs served
//! results against one-shot runs at the bit level.
//!
//! [`SocketSink`] is the [`ResultSink`] end of the pipe: every node
//! sink of a run frames its tiles into one shared writer (interleaved
//! at frame granularity — frames from different nodes never tear).

use std::io::{Read, Write};
use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Context, Result};

use crate::metrics::store::{PairEntry, TripleEntry};
use crate::metrics::MetricId;
use crate::output::sink::{NodeSink, ResultSink, Tile};

/// Current (and only) wire format version byte.
pub const WIRE_VERSION: u8 = 0x01;

/// Hard cap on a frame's declared payload length. A corrupt or hostile
/// length prefix must not make the decoder allocate gigabytes; tiles
/// are bounded by block size and sit far below this.
pub const MAX_FRAME_BYTES: u32 = 1 << 26; // 64 MiB

const KIND_PAIRS: u8 = 0x01;
const KIND_TRIPLES: u8 = 0x02;
const KIND_DONE: u8 = 0x03;
const KIND_ERROR: u8 = 0x04;

const PAIR_ENTRY_BYTES: u64 = 16; // i u32 + j u32 + value u64
const TRIPLE_ENTRY_BYTES: u64 = 20; // i u32 + j u32 + k u32 + value u64

/// Stable single-byte metric tags (additions append, never renumber —
/// the version byte only bumps for structural changes).
fn metric_code(metric: MetricId) -> u8 {
    match metric {
        MetricId::Czekanowski => 0,
        MetricId::Ccc => 1,
        MetricId::Sorenson => 2,
    }
}

fn metric_from_code(code: u8) -> Result<MetricId> {
    Ok(match code {
        0 => MetricId::Czekanowski,
        1 => MetricId::Ccc,
        2 => MetricId::Sorenson,
        other => bail!("wire: unknown metric code 0x{other:02x}"),
    })
}

/// Everything that travels on a serve connection, server → client.
///
/// A request's reply is zero or more `Tile` frames followed by exactly
/// one `Done` (success: metric count + checksum digest for client-side
/// diffing) or one `Error` (the request never ran or died mid-run).
#[derive(Debug, Clone)]
pub enum Frame {
    Tile(Tile),
    Done { metrics: u64, checksum: String },
    Error { message: String },
}

impl Frame {
    /// Encode into a complete frame (length prefix included).
    pub fn encode(&self) -> Vec<u8> {
        match self {
            Frame::Tile(tile) => tile.encode(),
            Frame::Done { metrics, checksum } => {
                let digest = checksum.as_bytes();
                let mut payload = Vec::with_capacity(2 + 8 + 4 + digest.len());
                payload.push(WIRE_VERSION);
                payload.push(KIND_DONE);
                payload.extend_from_slice(&metrics.to_le_bytes());
                payload.extend_from_slice(&(digest.len() as u32).to_le_bytes());
                payload.extend_from_slice(digest);
                prefix(payload)
            }
            Frame::Error { message } => {
                let msg = message.as_bytes();
                let mut payload = Vec::with_capacity(2 + 4 + msg.len());
                payload.push(WIRE_VERSION);
                payload.push(KIND_ERROR);
                payload.extend_from_slice(&(msg.len() as u32).to_le_bytes());
                payload.extend_from_slice(msg);
                prefix(payload)
            }
        }
    }

    /// Decode one complete frame from a byte slice. The slice must hold
    /// exactly one frame — a short slice is a truncation error, extra
    /// bytes after the frame are trailing garbage. Never panics.
    pub fn decode(bytes: &[u8]) -> Result<Frame> {
        ensure!(
            bytes.len() >= 4,
            "wire: truncated frame ({} byte(s), need a 4-byte length prefix)",
            bytes.len()
        );
        let declared = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
        ensure!(
            declared <= MAX_FRAME_BYTES,
            "wire: frame length {declared} exceeds the {MAX_FRAME_BYTES}-byte cap"
        );
        let body = &bytes[4..];
        let declared = declared as usize;
        ensure!(
            body.len() >= declared,
            "wire: truncated frame (payload declares {declared} byte(s), {} present)",
            body.len()
        );
        ensure!(
            body.len() == declared,
            "wire: {} byte(s) of trailing garbage after the frame",
            body.len() - declared
        );
        decode_payload(body)
    }

    /// Write the frame to a stream (no flush — callers flush at
    /// request boundaries).
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(&self.encode()).context("wire: write frame")?;
        Ok(())
    }

    /// Read one frame from a stream. `Ok(None)` on a clean EOF at a
    /// frame boundary; EOF mid-frame is an error.
    pub fn read_from(r: &mut impl Read) -> Result<Option<Frame>> {
        let mut len_buf = [0u8; 4];
        let mut got = 0;
        while got < 4 {
            let n = r.read(&mut len_buf[got..]).context("wire: read length prefix")?;
            if n == 0 {
                if got == 0 {
                    return Ok(None);
                }
                bail!("wire: stream closed mid-frame ({got} of 4 length byte(s) read)");
            }
            got += n;
        }
        let len = u32::from_le_bytes(len_buf);
        ensure!(
            len <= MAX_FRAME_BYTES,
            "wire: frame length {len} exceeds the {MAX_FRAME_BYTES}-byte cap"
        );
        let mut payload = vec![0u8; len as usize];
        r.read_exact(&mut payload).context("wire: read frame payload")?;
        decode_payload(&payload).map(Some)
    }
}

impl Tile {
    /// Encode into a complete wire frame (length prefix included).
    pub fn encode(&self) -> Vec<u8> {
        let mut payload = Vec::with_capacity(3 + 4 + self.len() * TRIPLE_ENTRY_BYTES as usize);
        payload.push(WIRE_VERSION);
        match self {
            Tile::Pairs { metric, entries } => {
                payload.push(KIND_PAIRS);
                payload.push(metric_code(*metric));
                payload.extend_from_slice(&(entries.len() as u32).to_le_bytes());
                for e in entries {
                    payload.extend_from_slice(&e.i.to_le_bytes());
                    payload.extend_from_slice(&e.j.to_le_bytes());
                    payload.extend_from_slice(&e.value.to_bits().to_le_bytes());
                }
            }
            Tile::Triples { metric, entries } => {
                payload.push(KIND_TRIPLES);
                payload.push(metric_code(*metric));
                payload.extend_from_slice(&(entries.len() as u32).to_le_bytes());
                for e in entries {
                    payload.extend_from_slice(&e.i.to_le_bytes());
                    payload.extend_from_slice(&e.j.to_le_bytes());
                    payload.extend_from_slice(&e.k.to_le_bytes());
                    payload.extend_from_slice(&e.value.to_bits().to_le_bytes());
                }
            }
        }
        prefix(payload)
    }

    /// Decode a frame that must hold a tile (strict: [`Frame::decode`]
    /// rules, plus `Done`/`Error` frames are rejected).
    pub fn decode(bytes: &[u8]) -> Result<Tile> {
        match Frame::decode(bytes)? {
            Frame::Tile(tile) => Ok(tile),
            Frame::Done { .. } => bail!("wire: expected a tile frame, got Done"),
            Frame::Error { .. } => bail!("wire: expected a tile frame, got Error"),
        }
    }
}

fn prefix(payload: Vec<u8>) -> Vec<u8> {
    debug_assert!(payload.len() as u64 <= MAX_FRAME_BYTES as u64);
    let mut frame = Vec::with_capacity(4 + payload.len());
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend(payload);
    frame
}

/// Decode a frame payload (everything after the length prefix).
fn decode_payload(payload: &[u8]) -> Result<Frame> {
    let mut rd = Reader::new(payload);
    let version = rd.u8("version")?;
    ensure!(
        version == WIRE_VERSION,
        "wire: unsupported version byte 0x{version:02x} (this build speaks 0x{WIRE_VERSION:02x})"
    );
    let kind = rd.u8("kind")?;
    let frame = match kind {
        KIND_PAIRS => {
            let metric = metric_from_code(rd.u8("metric")?)?;
            let count = rd.u32("entry count")? as u64;
            rd.expect_exact(count, PAIR_ENTRY_BYTES, "pair")?;
            let mut entries = Vec::with_capacity(count as usize);
            for _ in 0..count {
                let i = rd.u32("pair i")?;
                let j = rd.u32("pair j")?;
                let value = f64::from_bits(rd.u64("pair value")?);
                entries.push(PairEntry { i, j, value });
            }
            Frame::Tile(Tile::Pairs { metric, entries })
        }
        KIND_TRIPLES => {
            let metric = metric_from_code(rd.u8("metric")?)?;
            let count = rd.u32("entry count")? as u64;
            rd.expect_exact(count, TRIPLE_ENTRY_BYTES, "triple")?;
            let mut entries = Vec::with_capacity(count as usize);
            for _ in 0..count {
                let i = rd.u32("triple i")?;
                let j = rd.u32("triple j")?;
                let k = rd.u32("triple k")?;
                let value = f64::from_bits(rd.u64("triple value")?);
                entries.push(TripleEntry { i, j, k, value });
            }
            Frame::Tile(Tile::Triples { metric, entries })
        }
        KIND_DONE => {
            let metrics = rd.u64("metric count")?;
            let len = rd.u32("digest length")? as u64;
            rd.expect_exact(len, 1, "digest")?;
            let checksum = String::from_utf8(rd.bytes(len as usize, "digest")?.to_vec())
                .context("wire: checksum digest is not UTF-8")?;
            Frame::Done { metrics, checksum }
        }
        KIND_ERROR => {
            let len = rd.u32("message length")? as u64;
            rd.expect_exact(len, 1, "message")?;
            let message = String::from_utf8(rd.bytes(len as usize, "message")?.to_vec())
                .context("wire: error message is not UTF-8")?;
            Frame::Error { message }
        }
        other => bail!("wire: unknown frame kind 0x{other:02x}"),
    };
    rd.expect_empty()?;
    Ok(frame)
}

/// Bounds-checked little-endian cursor — every read names the field it
/// was after, so truncation errors say what was missing.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn remaining(&self) -> u64 {
        (self.buf.len() - self.pos) as u64
    }

    fn bytes(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        ensure!(
            self.remaining() >= n as u64,
            "wire: truncated payload reading {what} (need {n} byte(s), {} left)",
            self.remaining()
        );
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.bytes(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.bytes(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.bytes(8, what)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// The declared element count must account for *exactly* the bytes
    /// left — checked up front (u64 math, no overflow) so a hostile
    /// count neither over-allocates nor leaves silent garbage.
    fn expect_exact(&self, count: u64, elem_bytes: u64, what: &str) -> Result<()> {
        let need = count.checked_mul(elem_bytes).context("wire: element count overflows")?;
        ensure!(
            need == self.remaining(),
            "wire: {what} section declares {need} byte(s) but {} remain in the frame",
            self.remaining()
        );
        Ok(())
    }

    fn expect_empty(&self) -> Result<()> {
        ensure!(
            self.remaining() == 0,
            "wire: {} byte(s) of trailing garbage inside the frame payload",
            self.remaining()
        );
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// SocketSink — the serving end of ResultSink.

/// Streams every tile of a run as wire frames into one shared writer.
///
/// All node sinks of the run share the writer behind a mutex; each tile
/// is encoded outside the lock and written with a single `write_all`,
/// so frames interleave between nodes but never tear. `W: 'static`
/// because node sinks move into the coordinator's node threads.
pub struct SocketSink<W: Write + Send + 'static> {
    writer: Arc<Mutex<W>>,
}

impl<W: Write + Send + 'static> SocketSink<W> {
    pub fn new(writer: W) -> Self {
        SocketSink { writer: Arc::new(Mutex::new(writer)) }
    }

    /// Wrap an already-shared writer — `comet serve` threads the same
    /// handle through the sink *and* the Done/Error frame writer, so a
    /// request's frames serialize onto the connection in order.
    pub fn shared(writer: Arc<Mutex<W>>) -> Self {
        SocketSink { writer }
    }

    pub fn writer(&self) -> Arc<Mutex<W>> {
        Arc::clone(&self.writer)
    }
}

impl<W: Write + Send + 'static> ResultSink for SocketSink<W> {
    fn node_sink(&self, _rank: usize) -> Result<Box<dyn NodeSink>> {
        Ok(Box::new(SocketNode { writer: Arc::clone(&self.writer) }))
    }
}

struct SocketNode<W: Write + Send + 'static> {
    writer: Arc<Mutex<W>>,
}

impl<W: Write + Send + 'static> NodeSink for SocketNode<W> {
    fn tile(&mut self, tile: Tile) -> Result<()> {
        if tile.is_empty() {
            return Ok(()); // empty tiles carry no information a client needs
        }
        let frame = tile.encode();
        let mut w = self.writer.lock().unwrap();
        w.write_all(&frame).context("wire: stream tile frame")?;
        Ok(())
    }

    fn finish(&mut self) -> Result<()> {
        self.writer.lock().unwrap().flush().context("wire: flush tile stream")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Gen;

    fn arb_pairs(g: &mut Gen) -> Tile {
        let metric = *g.pick(&[MetricId::Czekanowski, MetricId::Ccc, MetricId::Sorenson]);
        let n = g.usize_in(0, 40);
        let entries = (0..n)
            .map(|_| PairEntry {
                i: arb_index(g),
                j: arb_index(g),
                value: arb_value(g),
            })
            .collect();
        Tile::Pairs { metric, entries }
    }

    fn arb_triples(g: &mut Gen) -> Tile {
        let metric = *g.pick(&[MetricId::Czekanowski, MetricId::Ccc, MetricId::Sorenson]);
        let n = g.usize_in(0, 40);
        let entries = (0..n)
            .map(|_| TripleEntry {
                i: arb_index(g),
                j: arb_index(g),
                k: arb_index(g),
                value: arb_value(g),
            })
            .collect();
        Tile::Triples { metric, entries }
    }

    /// Indices biased toward the edges: 0 and u32::MAX must survive.
    fn arb_index(g: &mut Gen) -> u32 {
        match g.usize_in(0, 4) {
            0 => 0,
            1 => u32::MAX,
            _ => g.usize_in(0, u32::MAX as usize) as u32,
        }
    }

    /// Values across the full f64 bit space (infinities, NaN payloads,
    /// subnormals) — round-trip compares bits, not ==.
    fn arb_value(g: &mut Gen) -> f64 {
        let hi = g.usize_in(0, u32::MAX as usize) as u64;
        let lo = g.usize_in(0, u32::MAX as usize) as u64;
        f64::from_bits((hi << 32) | lo)
    }

    fn tiles_bit_equal(a: &Tile, b: &Tile) -> bool {
        match (a, b) {
            (Tile::Pairs { metric: ma, entries: ea }, Tile::Pairs { metric: mb, entries: eb }) => {
                ma == mb
                    && ea.len() == eb.len()
                    && ea.iter().zip(eb).all(|(x, y)| {
                        x.i == y.i && x.j == y.j && x.value.to_bits() == y.value.to_bits()
                    })
            }
            (
                Tile::Triples { metric: ma, entries: ea },
                Tile::Triples { metric: mb, entries: eb },
            ) => {
                ma == mb
                    && ea.len() == eb.len()
                    && ea.iter().zip(eb).all(|(x, y)| {
                        x.i == y.i
                            && x.j == y.j
                            && x.k == y.k
                            && x.value.to_bits() == y.value.to_bits()
                    })
            }
            _ => false,
        }
    }

    #[test]
    fn prop_tile_round_trip() {
        crate::testkit::forall(
            "wire-tile-round-trip",
            300,
            |g| if g.bool() { arb_pairs(g) } else { arb_triples(g) },
            |tile| {
                let frame = tile.encode();
                let back = Tile::decode(&frame).map_err(|e| format!("decode: {e:#}"))?;
                if !tiles_bit_equal(tile, &back) {
                    return Err("round-trip changed the tile".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_truncation_always_errors_never_panics() {
        crate::testkit::forall(
            "wire-truncation",
            200,
            |g| {
                let tile = if g.bool() { arb_pairs(g) } else { arb_triples(g) };
                let frame = tile.encode();
                let cut = g.usize_in(0, frame.len().saturating_sub(1));
                (frame, cut)
            },
            |(frame, cut)| match Frame::decode(&frame[..*cut]) {
                Err(_) => Ok(()),
                Ok(_) => Err(format!("truncation to {cut} of {} decoded", frame.len())),
            },
        );
    }

    #[test]
    fn empty_and_max_index_tiles_round_trip() {
        for tile in [
            Tile::Pairs { metric: MetricId::Sorenson, entries: vec![] },
            Tile::Triples { metric: MetricId::Czekanowski, entries: vec![] },
            Tile::Pairs {
                metric: MetricId::Ccc,
                entries: vec![PairEntry { i: u32::MAX, j: u32::MAX, value: f64::NAN }],
            },
            Tile::Triples {
                metric: MetricId::Czekanowski,
                entries: vec![TripleEntry {
                    i: 0,
                    j: u32::MAX,
                    k: u32::MAX - 1,
                    value: -0.0,
                }],
            },
        ] {
            let back = Tile::decode(&tile.encode()).unwrap();
            assert!(tiles_bit_equal(&tile, &back), "{tile:?}");
        }
    }

    #[test]
    fn bad_version_kind_metric_rejected() {
        let good = Tile::Pairs {
            metric: MetricId::Czekanowski,
            entries: vec![PairEntry { i: 1, j: 2, value: 0.5 }],
        }
        .encode();

        let mut bad_version = good.clone();
        bad_version[4] = 0x7f; // payload byte 0
        assert!(Frame::decode(&bad_version).unwrap_err().to_string().contains("version"));

        let mut bad_kind = good.clone();
        bad_kind[5] = 0x66; // payload byte 1
        assert!(Frame::decode(&bad_kind).unwrap_err().to_string().contains("kind"));

        let mut bad_metric = good.clone();
        bad_metric[6] = 0xee; // payload byte 2
        assert!(Frame::decode(&bad_metric).unwrap_err().to_string().contains("metric"));
    }

    #[test]
    fn trailing_garbage_rejected_both_layers() {
        let mut frame = Tile::Pairs { metric: MetricId::Ccc, entries: vec![] }.encode();
        // After the frame: slice-level garbage.
        frame.push(0xaa);
        let err = Frame::decode(&frame).unwrap_err().to_string();
        assert!(err.contains("trailing garbage"), "{err}");

        // Inside the payload: length prefix covers bytes the body
        // doesn't account for.
        let mut inner = Tile::Pairs { metric: MetricId::Ccc, entries: vec![] }.encode();
        inner.push(0xbb);
        let len = (inner.len() - 4) as u32;
        inner[..4].copy_from_slice(&len.to_le_bytes());
        let err = Frame::decode(&inner).unwrap_err().to_string();
        assert!(err.contains("remain in the frame") || err.contains("trailing garbage"), "{err}");
    }

    #[test]
    fn hostile_lengths_do_not_allocate() {
        // Length prefix far past the cap.
        let mut frame = vec![0u8; 8];
        frame[..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(Frame::decode(&frame).unwrap_err().to_string().contains("cap"));

        // Entry count that would overflow count * entry_size.
        let mut payload = vec![WIRE_VERSION, KIND_PAIRS, 0];
        payload.extend_from_slice(&u32::MAX.to_le_bytes());
        let framed = prefix(payload);
        assert!(Frame::decode(&framed).is_err());
    }

    #[test]
    fn done_and_error_frames_round_trip() {
        let done = Frame::Done { metrics: 1234, checksum: "0abc42".into() };
        match Frame::decode(&done.encode()).unwrap() {
            Frame::Done { metrics, checksum } => {
                assert_eq!(metrics, 1234);
                assert_eq!(checksum, "0abc42");
            }
            other => panic!("expected Done, got {other:?}"),
        }

        let err = Frame::Error { message: "queue full".into() };
        match Frame::decode(&err.encode()).unwrap() {
            Frame::Error { message } => assert_eq!(message, "queue full"),
            other => panic!("expected Error, got {other:?}"),
        }
    }

    #[test]
    fn read_from_streams_frames_and_detects_clean_eof() {
        let tiles = vec![
            Tile::Pairs {
                metric: MetricId::Sorenson,
                entries: vec![PairEntry { i: 0, j: 9, value: 0.25 }],
            },
            Tile::Triples {
                metric: MetricId::Czekanowski,
                entries: vec![TripleEntry { i: 1, j: 2, k: 3, value: 0.75 }],
            },
        ];
        let mut stream = Vec::new();
        for t in &tiles {
            stream.extend(t.encode());
        }
        Frame::Done { metrics: 2, checksum: "xyz".into() }.write_to(&mut stream).unwrap();

        let mut cursor = std::io::Cursor::new(stream.clone());
        for t in &tiles {
            match Frame::read_from(&mut cursor).unwrap().unwrap() {
                Frame::Tile(back) => assert!(tiles_bit_equal(t, &back)),
                other => panic!("expected tile, got {other:?}"),
            }
        }
        assert!(matches!(Frame::read_from(&mut cursor).unwrap(), Some(Frame::Done { .. })));
        assert!(Frame::read_from(&mut cursor).unwrap().is_none(), "clean EOF");

        // EOF mid-frame is an error, not None (the first frame is
        // longer than 10 bytes, so the payload read hits EOF).
        let mut cut = std::io::Cursor::new(stream[..10].to_vec());
        assert!(Frame::read_from(&mut cut).is_err());
    }

    #[test]
    fn socket_sink_stream_decodes_back() {
        let sink = SocketSink::new(Vec::<u8>::new());
        let writer = sink.writer();
        let mut node = sink.node_sink(0).unwrap();
        let tile = Tile::Pairs {
            metric: MetricId::Ccc,
            entries: vec![PairEntry { i: 3, j: 4, value: 1.0 }],
        };
        node.tile(tile.clone()).unwrap();
        node.tile(Tile::Pairs { metric: MetricId::Ccc, entries: vec![] }).unwrap(); // dropped
        node.finish().unwrap();

        let bytes = writer.lock().unwrap().clone();
        let mut cursor = std::io::Cursor::new(bytes);
        match Frame::read_from(&mut cursor).unwrap().unwrap() {
            Frame::Tile(back) => assert!(tiles_bit_equal(&tile, &back)),
            other => panic!("expected tile, got {other:?}"),
        }
        assert!(Frame::read_from(&mut cursor).unwrap().is_none());
    }
}
