//! Streaming result sinks: the coordinator's node programs emit
//! finished metric **tiles** ([`Tile`]) through a [`ResultSink`]
//! instead of hard-coding store-vs-file-vs-drop.
//!
//! A tile is the batch of metric values assembled from one numerator
//! block (2-way) or one pivot chunk of a slice (3-way) — bounded by the
//! block size, never by the campaign size, so a server can forward
//! tiles to clients without ever holding a full result set in memory.
//! The built-in sinks reproduce the three historical output modes:
//!
//! * [`CollectSink`] — accumulate into [`PairStore`]/[`TripleStore`]
//!   (the old `store_metrics: true` behavior; examples/tests).
//! * [`FileSink`] — stream to per-node §6.8 byte files through
//!   [`NodeWriter`], with optional thresholding (the old `output_dir`
//!   behavior; byte-identical files).
//! * [`DiscardSink`] / [`StatsOnlySink`] — drop values (the old
//!   `--no-store` behavior), optionally counting tiles/values.
//!
//! [`ForwardSink`] adapts a closure (the serving path: push tiles to a
//! socket, a channel, a live reducer), and [`TeeRef`] fans one run out
//! to several sinks (collect *and* write files, as the legacy
//! `coordinator::run` contract requires).
//!
//! Concurrency model: one [`NodeSink`] per emitting virtual node
//! (created by [`ResultSink::node_sink`] before the node threads
//! spawn), so per-node state (file writers, local buffers) needs no
//! locking; shared aggregation happens in `NodeSink::finish` or behind
//! the sink's own synchronization.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::RunStats;
use crate::metrics::indexing;
use crate::metrics::store::{PairEntry, PairStore, TripleEntry, TripleStore};
use crate::metrics::MetricId;
use crate::output::NodeWriter;
use crate::vecdata::block::Repr;

/// One finished batch of metric values, tagged with the metric family
/// that produced it. Entries are canonical (i < j (< k)) and appear in
/// the node program's emission order (which the §6.8 file format
/// depends on in dense mode).
#[derive(Debug, Clone)]
pub enum Tile {
    Pairs {
        metric: MetricId,
        entries: Vec<PairEntry>,
    },
    Triples {
        metric: MetricId,
        entries: Vec<TripleEntry>,
    },
}

impl Tile {
    pub fn len(&self) -> usize {
        match self {
            Tile::Pairs { entries, .. } => entries.len(),
            Tile::Triples { entries, .. } => entries.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn metric(&self) -> MetricId {
        match self {
            Tile::Pairs { metric, .. } | Tile::Triples { metric, .. } => *metric,
        }
    }
}

/// Per-node tile consumer. Moved into the node's thread; `finish` is
/// called exactly once after the node's last tile (flush point).
pub trait NodeSink: Send {
    fn tile(&mut self, tile: Tile) -> Result<()>;

    fn finish(&mut self) -> Result<()> {
        Ok(())
    }
}

/// A run-level sink: hands out one [`NodeSink`] per emitting virtual
/// node. Implementations own whatever shared state their node sinks
/// aggregate into.
pub trait ResultSink: Send + Sync {
    fn node_sink(&self, rank: usize) -> Result<Box<dyn NodeSink>>;

    /// True when tiles would be dropped unseen — the coordinator skips
    /// tile assembly entirely then (the `--no-store` fast path).
    fn is_null(&self) -> bool {
        false
    }

    /// Called once by the coordinator after every node finished, with
    /// the run's lowered config and final stats. [`FileSink`] uses it
    /// to write the `run.meta` sidecar next to its metric files (the
    /// §6.8 byte files are headerless, so the sidecar travels with
    /// whoever writes them — not with a config field that may name a
    /// different directory). Default: no-op.
    fn on_run_complete(
        &self,
        _cfg: &RunConfig,
        _repr: Repr,
        _diag_kernel: &'static str,
        _stats: &RunStats,
    ) -> Result<()> {
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Collect — today's in-memory stores.

/// Accumulates tiles into metric-tagged stores. Node sinks buffer
/// locally and park their stores (tagged with their rank) at `finish`;
/// [`CollectSink::take`] merges them in **rank order**, reproducing
/// the deterministic join-order merge of the pre-sink coordinator —
/// entry order (and therefore `top_k` tie-breaking) is identical
/// run-to-run however the node threads raced.
/// Per-node parked stores, keyed by rank (shared with the node sinks —
/// they outlive the borrow of the parent, living in node threads).
type CollectedParts = Arc<Mutex<Vec<(usize, PairStore, TripleStore)>>>;

#[derive(Debug)]
pub struct CollectSink {
    metric: MetricId,
    parts: CollectedParts,
}

impl Default for CollectSink {
    fn default() -> Self {
        Self::for_metric(MetricId::default())
    }
}

impl CollectSink {
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty collector whose stores carry `metric` tags even if the
    /// run emits nothing.
    pub fn for_metric(metric: MetricId) -> Self {
        CollectSink { metric, parts: Arc::new(Mutex::new(Vec::new())) }
    }

    /// Drain everything collected so far, merged in rank order.
    pub fn take(&self) -> (PairStore, TripleStore) {
        let mut parts = std::mem::take(&mut *self.parts.lock().unwrap());
        parts.sort_by_key(|(rank, _, _)| *rank);
        let mut pairs = PairStore::for_metric(self.metric);
        let mut triples = TripleStore::for_metric(self.metric);
        for (_, p, t) in parts {
            if !p.is_empty() {
                pairs.metric = p.metric;
            }
            pairs.extend(p);
            if !t.is_empty() {
                triples.metric = t.metric;
            }
            triples.extend(t);
        }
        (pairs, triples)
    }
}

impl ResultSink for CollectSink {
    fn node_sink(&self, rank: usize) -> Result<Box<dyn NodeSink>> {
        Ok(Box::new(CollectNode {
            rank,
            pairs: PairStore::new(),
            triples: TripleStore::new(),
            parts: Arc::clone(&self.parts),
        }))
    }
}

struct CollectNode {
    rank: usize,
    pairs: PairStore,
    triples: TripleStore,
    parts: CollectedParts,
}

impl NodeSink for CollectNode {
    fn tile(&mut self, tile: Tile) -> Result<()> {
        match tile {
            Tile::Pairs { metric, entries } => {
                self.pairs.metric = metric;
                self.pairs.extend_entries(entries);
            }
            Tile::Triples { metric, entries } => {
                self.triples.metric = metric;
                self.triples.extend_entries(entries);
            }
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<()> {
        if !self.pairs.is_empty() || !self.triples.is_empty() {
            self.parts.lock().unwrap().push((
                self.rank,
                std::mem::take(&mut self.pairs),
                std::mem::take(&mut self.triples),
            ));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// File — today's §6.8 per-node byte files.

/// Streams tiles to per-node metric files (`metrics_<rank>.bin`)
/// through [`NodeWriter`] — dense value bytes, or (offset, byte)
/// records when `threshold` is set. Produces byte-identical files to
/// the pre-sink coordinator.
#[derive(Debug, Clone)]
pub struct FileSink {
    dir: PathBuf,
    threshold: Option<f64>,
}

impl FileSink {
    pub fn new(dir: impl Into<PathBuf>, threshold: Option<f64>) -> Self {
        FileSink { dir: dir.into(), threshold }
    }
}

impl ResultSink for FileSink {
    fn node_sink(&self, rank: usize) -> Result<Box<dyn NodeSink>> {
        Ok(Box::new(FileNode {
            writer: Some(NodeWriter::create(&self.dir, rank, self.threshold)?),
        }))
    }

    fn on_run_complete(
        &self,
        cfg: &RunConfig,
        repr: Repr,
        diag_kernel: &'static str,
        stats: &RunStats,
    ) -> Result<()> {
        crate::output::write_run_meta(&self.dir, cfg, repr, diag_kernel, stats)?;
        Ok(())
    }
}

struct FileNode {
    writer: Option<NodeWriter>,
}

impl NodeSink for FileNode {
    fn tile(&mut self, tile: Tile) -> Result<()> {
        let Some(w) = self.writer.as_mut() else {
            return Ok(());
        };
        match &tile {
            Tile::Pairs { entries, .. } => {
                for e in entries {
                    w.write(indexing::pair_offset(e.i as usize, e.j as usize) as u64, e.value)?;
                }
            }
            Tile::Triples { entries, .. } => {
                for e in entries {
                    w.write(
                        indexing::triple_offset(e.i as usize, e.j as usize, e.k as usize) as u64,
                        e.value,
                    )?;
                }
            }
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<()> {
        if let Some(w) = self.writer.take() {
            w.finish()?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Stats-only / discard — today's `--no-store`.

/// Counts tiles and values without retaining them. `max_tile_len`
/// doubles as the test probe for the no-materialization contract: it
/// stays bounded by the block size while a campaign's total grows.
#[derive(Debug, Default)]
pub struct StatsOnlySink {
    counts: Arc<SinkCounts>,
}

#[derive(Debug, Default)]
struct SinkCounts {
    tiles: AtomicU64,
    values: AtomicU64,
    max_tile: AtomicU64,
}

impl StatsOnlySink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn tiles(&self) -> u64 {
        self.counts.tiles.load(Ordering::Relaxed)
    }

    pub fn values(&self) -> u64 {
        self.counts.values.load(Ordering::Relaxed)
    }

    pub fn max_tile_len(&self) -> u64 {
        self.counts.max_tile.load(Ordering::Relaxed)
    }
}

impl ResultSink for StatsOnlySink {
    fn node_sink(&self, _rank: usize) -> Result<Box<dyn NodeSink>> {
        Ok(Box::new(StatsNode { counts: Arc::clone(&self.counts) }))
    }
}

struct StatsNode {
    counts: Arc<SinkCounts>,
}

impl NodeSink for StatsNode {
    fn tile(&mut self, tile: Tile) -> Result<()> {
        let n = tile.len() as u64;
        self.counts.tiles.fetch_add(1, Ordering::Relaxed);
        self.counts.values.fetch_add(n, Ordering::Relaxed);
        self.counts.max_tile.fetch_max(n, Ordering::Relaxed);
        Ok(())
    }
}

/// Drops every tile; reports [`ResultSink::is_null`] so the node
/// programs skip tile assembly altogether.
#[derive(Debug, Default, Clone, Copy)]
pub struct DiscardSink;

impl ResultSink for DiscardSink {
    fn node_sink(&self, _rank: usize) -> Result<Box<dyn NodeSink>> {
        Ok(Box::new(DiscardNode))
    }

    fn is_null(&self) -> bool {
        true
    }
}

struct DiscardNode;

impl NodeSink for DiscardNode {
    fn tile(&mut self, _tile: Tile) -> Result<()> {
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Forward — the serving seam.

type ForwardFn = dyn Fn(usize, Tile) -> Result<()> + Send + Sync;

/// Forwards each (rank, tile) to a closure as it is produced — the
/// hook a server uses to push results onward (socket, channel, live
/// reducer) with memory bounded by one tile. The closure is shared by
/// every node sink and may be called from node threads concurrently;
/// wrap interior state accordingly.
pub struct ForwardSink {
    f: Arc<ForwardFn>,
}

impl ForwardSink {
    pub fn new(f: impl Fn(usize, Tile) -> Result<()> + Send + Sync + 'static) -> Self {
        ForwardSink { f: Arc::new(f) }
    }
}

impl ResultSink for ForwardSink {
    fn node_sink(&self, rank: usize) -> Result<Box<dyn NodeSink>> {
        Ok(Box::new(ForwardNode { rank, f: Arc::clone(&self.f) }))
    }
}

struct ForwardNode {
    rank: usize,
    f: Arc<ForwardFn>,
}

impl NodeSink for ForwardNode {
    fn tile(&mut self, tile: Tile) -> Result<()> {
        (self.f)(self.rank, tile)
    }
}

// ---------------------------------------------------------------------------
// Tee — compose sinks.

/// Fans every tile out to several sinks (collect *and* file, say).
/// Borrowing, so a run can compose a caller's sink with run-scoped
/// locals (the way `session::Session::run` rides a request's file sink
/// alongside whatever the caller listens with) without `Arc` plumbing.
/// An empty (or all-null) tee is null; null members are skipped at
/// node-sink creation so tiles are never cloned just to be dropped.
pub struct TeeRef<'a> {
    sinks: Vec<&'a dyn ResultSink>,
}

impl<'a> TeeRef<'a> {
    pub fn new(sinks: Vec<&'a dyn ResultSink>) -> Self {
        TeeRef { sinks }
    }
}

impl ResultSink for TeeRef<'_> {
    fn node_sink(&self, rank: usize) -> Result<Box<dyn NodeSink>> {
        let sinks = self
            .sinks
            .iter()
            .filter(|s| !s.is_null())
            .map(|s| s.node_sink(rank))
            .collect::<Result<Vec<_>>>()?;
        Ok(Box::new(TeeNode { sinks }))
    }

    fn is_null(&self) -> bool {
        self.sinks.iter().all(|s| s.is_null())
    }

    fn on_run_complete(
        &self,
        cfg: &RunConfig,
        repr: Repr,
        diag_kernel: &'static str,
        stats: &RunStats,
    ) -> Result<()> {
        for s in &self.sinks {
            s.on_run_complete(cfg, repr, diag_kernel, stats)?;
        }
        Ok(())
    }
}

struct TeeNode {
    sinks: Vec<Box<dyn NodeSink>>,
}

impl NodeSink for TeeNode {
    fn tile(&mut self, tile: Tile) -> Result<()> {
        if let Some((last, rest)) = self.sinks.split_last_mut() {
            for s in rest.iter_mut() {
                s.tile(tile.clone())?;
            }
            last.tile(tile)?;
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<()> {
        for s in &mut self.sinks {
            s.finish()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::output::read_dense;

    fn pair_tile(metric: MetricId, pairs: &[(u32, u32, f64)]) -> Tile {
        Tile::Pairs {
            metric,
            entries: pairs.iter().map(|&(i, j, value)| PairEntry { i, j, value }).collect(),
        }
    }

    #[test]
    fn collect_sink_merges_nodes_with_tags() {
        let sink = CollectSink::for_metric(MetricId::Ccc);
        let mut a = sink.node_sink(0).unwrap();
        let mut b = sink.node_sink(1).unwrap();
        a.tile(pair_tile(MetricId::Ccc, &[(0, 1, 0.5)])).unwrap();
        b.tile(pair_tile(MetricId::Ccc, &[(1, 2, 0.25), (0, 3, 0.75)])).unwrap();
        a.finish().unwrap();
        b.finish().unwrap();
        let (pairs, triples) = sink.take();
        assert_eq!(pairs.len(), 3);
        assert_eq!(pairs.metric, MetricId::Ccc);
        assert!(triples.is_empty());
        // take() drains.
        assert!(sink.take().0.is_empty());
    }

    #[test]
    fn file_sink_matches_direct_node_writer() {
        let dir = std::env::temp_dir().join(format!("comet-sink-{}", std::process::id()));
        let sink = FileSink::new(dir.join("a"), None);
        let mut node = sink.node_sink(2).unwrap();
        node.tile(pair_tile(MetricId::Czekanowski, &[(0, 1, 0.5), (0, 2, 1.0)])).unwrap();
        node.finish().unwrap();
        let via_sink = read_dense(&dir.join("a").join("metrics_2.bin")).unwrap();

        let mut w = NodeWriter::create(&dir.join("b"), 2, None).unwrap();
        w.write(indexing::pair_offset(0, 1) as u64, 0.5).unwrap();
        w.write(indexing::pair_offset(0, 2) as u64, 1.0).unwrap();
        let (path, n) = w.finish().unwrap();
        assert_eq!(n, 2);
        assert_eq!(via_sink, read_dense(&path).unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn file_sink_writes_run_meta_on_complete() {
        let dir = std::env::temp_dir().join(format!("comet-sink-meta-{}", std::process::id()));
        let sink = FileSink::new(&dir, None);
        let cfg = RunConfig::default();
        let stats = RunStats { metrics: 7, ..Default::default() };
        sink.on_run_complete(&cfg, Repr::Float, "triangular", &stats).unwrap();
        let doc = crate::output::read_run_meta(&dir).unwrap();
        assert_eq!(doc.get("run", "metric").unwrap().as_str().unwrap(), "czekanowski");
        assert_eq!(doc.get("run", "kernel").unwrap().as_str().unwrap(), "triangular");
        assert_eq!(doc.get("run", "metrics").unwrap().as_int().unwrap(), 7);
        // The other sinks no-op.
        DiscardSink.on_run_complete(&cfg, Repr::Float, "full", &stats).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tee_fans_out_and_empty_tee_is_null() {
        let collect = CollectSink::new();
        let stats = StatsOnlySink::new();
        let tee = TeeRef::new(vec![&collect as &dyn ResultSink, &stats as &dyn ResultSink]);
        assert!(!tee.is_null());
        let mut node = tee.node_sink(0).unwrap();
        node.tile(pair_tile(MetricId::Sorenson, &[(0, 1, 0.5), (2, 3, 0.1)])).unwrap();
        node.finish().unwrap();
        assert_eq!(collect.take().0.len(), 2);
        assert_eq!(stats.tiles(), 1);
        assert_eq!(stats.values(), 2);
        assert_eq!(stats.max_tile_len(), 2);
        assert!(TeeRef::new(vec![]).is_null());
        assert!(TeeRef::new(vec![&DiscardSink as &dyn ResultSink]).is_null());
        assert!(DiscardSink.is_null());
    }

    #[test]
    fn forward_sink_streams_to_closure() {
        let seen = Arc::new(Mutex::new(Vec::new()));
        let seen2 = Arc::clone(&seen);
        let sink = ForwardSink::new(move |rank, tile| {
            seen2.lock().unwrap().push((rank, tile.len()));
            Ok(())
        });
        let mut a = sink.node_sink(0).unwrap();
        let mut b = sink.node_sink(3).unwrap();
        a.tile(pair_tile(MetricId::Czekanowski, &[(0, 1, 1.0)])).unwrap();
        b.tile(pair_tile(MetricId::Czekanowski, &[(0, 2, 1.0), (1, 2, 0.0)])).unwrap();
        let got = seen.lock().unwrap().clone();
        assert_eq!(got, vec![(0, 1), (3, 2)]);
    }
}
