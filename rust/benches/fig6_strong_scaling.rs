//! Figure 6 — strong scaling, 2-way and 3-way, double precision.
//!
//! Fixed problem, growing node count (paper: 2→64 Titan nodes, best
//! decomposition per count; parallel efficiency 79% / 34%).
//!
//! On one physical core, virtual-node wall-clock cannot show speedup;
//! we therefore report the two quantities the paper's curves are built
//! from: (a) measured per-node *work* (max blocks/slices per node —
//! the load-balance component of strong scaling) and (b) the §6.3
//! model-projected runtime combining measured single-node kernel rates
//! with the comm cost model — the same methodology the paper's model
//! section validates.

use comet::comm::cost::CostModel;
use comet::config::{BackendKind, InputSource, Precision, RunConfig};
use comet::coordinator::run;
use comet::decomp::{three_way, two_way, Grid};
use comet::metrics::counts;
use comet::perfmodel::{self, ModelInput};
use comet::util::fmt;
use comet::vecdata::SyntheticKind;

fn main() {
    // Scaled problem: nv fixed, nodes 2..8 (paper: 16,384 / 1,544
    // vectors on 2..64 nodes).
    let nf = 384usize;
    let nv2 = 512usize;
    let nv3 = 120usize;

    // Measure the single-node mGEMM rate once (native backend — the
    // kernel-rate source for the model).
    let probe = RunConfig {
        num_way: 2,
        nv: 256,
        nf,
        precision: Precision::F64,
        backend: BackendKind::CpuOptimized,
        grid: Grid::new(1, 1, 1),
        input: InputSource::Synthetic { kind: SyntheticKind::RandomGrid, seed: 4 },
        store_metrics: false,
        ..Default::default()
    };
    let out = run(&probe).unwrap();
    let ops = counts::ops_2way_numerators(nf, 256) as f64;
    let gemm_rate = ops / out.stats.t_compute; // ops/s on this host
    println!(
        "Figure 6 — strong scaling (fixed problem), DP. kernel rate probe: {}\n",
        fmt::rate(gemm_rate)
    );

    let mut table = fmt::Table::new(&[
        "np", "2way max-load", "2way balance", "2way t_model", "2way eff",
        "3way max-slices", "3way t_model", "3way eff",
    ]);
    let mut t2_first = 0.0;
    let mut t3_first = 0.0;
    let mut np_first = 0;
    for np in [2usize, 4, 8, 16, 32, 64] {
        // Best decomposition: npv = np (pure vector split) vs npv·npr.
        let (npv2, npr2) = best_grid_2way(np);
        let nvp2 = nv2.div_ceil(npv2);
        let loads: Vec<usize> = (0..npv2)
            .flat_map(|pv| (0..npr2).map(move |pr| two_way::blocks_per_node(npv2, npr2, pv, pr)))
            .collect();
        let lmax = *loads.iter().max().unwrap();
        let lmin = *loads.iter().min().unwrap();
        let t_block = counts::ops_mgemm_block(nf, nvp2, nvp2) as f64 / gemm_rate;
        let m2 = ModelInput {
            nfp: nf,
            nvp: nvp2,
            elem_bytes: 8,
            t_gemm: t_block,
            t_cpu: 0.05 * t_block,
            load: lmax,
            diag_load: 0,
            threads: 1,
            lane_width: 1,
            t_spawn: 0.0,
            pool_warm: true,
            triangular: false,
            nst: 1,
            reload_frac: 0.0,
            disk_bw: 2e9,
            prefetch: true,
            retry_rate: 0.0,
            t_backoff: 0.0,
            ckpt_frac: 0.0,
            ckpt_bw: 0.0,
            ingest_bytes: 0,
            ingest_bw: 0.0,
            net: CostModel::gemini(),
            link: CostModel::pcie2(),
        };
        let t2 = perfmodel::predict_2way(&m2).total;

        let (npv3, npr3) = best_grid_3way(np);
        let nvp3 = nv3.div_ceil(npv3);
        let smax = (0..npv3)
            .flat_map(|pv| {
                (0..npr3).map(move |pr| three_way::slices_for_node(npv3, npr3, pv, pr).len())
            })
            .max()
            .unwrap();
        let t_block3 = counts::ops_mgemm3_slab(nf, 6, nvp3, nvp3) as f64 / gemm_rate;
        let m3 = ModelInput {
            nfp: nf,
            nvp: nvp3,
            elem_bytes: 8,
            t_gemm: t_block3,
            t_cpu: 0.05 * t_block3,
            load: smax,
            diag_load: 0,
            threads: 1,
            lane_width: 1,
            t_spawn: 0.0,
            pool_warm: true,
            triangular: false,
            nst: 1,
            reload_frac: 0.0,
            disk_bw: 2e9,
            prefetch: true,
            retry_rate: 0.0,
            t_backoff: 0.0,
            ckpt_frac: 0.0,
            ckpt_bw: 0.0,
            ingest_bytes: 0,
            ingest_bw: 0.0,
            net: CostModel::gemini(),
            link: CostModel::pcie2(),
        };
        let t3 = perfmodel::predict_3way(&m3).total;

        if np_first == 0 {
            np_first = np;
            t2_first = t2;
            t3_first = t3;
        }
        let eff2 = t2_first * np_first as f64 / (t2 * np as f64);
        let eff3 = t3_first * np_first as f64 / (t3 * np as f64);
        table.row(&[
            np.to_string(),
            format!("{lmax}"),
            format!("{lmin}..{lmax}"),
            fmt::secs(t2),
            format!("{:.0}%", 100.0 * eff2),
            format!("{smax}"),
            fmt::secs(t3),
            format!("{:.0}%", 100.0 * eff3),
        ]);
    }
    table.print();
    println!("\npaper Figure 6: 79% (2-way) and 34% (3-way) efficiency at 64 vs 2 nodes;");
    println!("3-way drops faster because the fixed problem leaves tiny per-node blocks —");
    println!("the same crossover the model rows above reproduce.");
}

fn best_grid_2way(np: usize) -> (usize, usize) {
    // Prefer pure vector decomposition until blocks get thin, then npr.
    for npv in (1..=np).rev() {
        if np % npv == 0 && npv <= 16 {
            return (npv, np / npv);
        }
    }
    (np, 1)
}

fn best_grid_3way(np: usize) -> (usize, usize) {
    for npv in (1..=np).rev() {
        if np % npv == 0 && npv <= 8 {
            return (npv, np / npv);
        }
    }
    (np, 1)
}
