//! Table 1 — single-accelerator kernel times: mGEMM lowerings vs. true
//! GEMM comparators, single and double precision.
//!
//! Paper rows → our rows:
//!   mGEMM, c += a<b?a:b           → mgemm2ternary (select lowering) + pallas ternary
//!   mGEMM, CUDA intrinsic fmin    → mgemm2 (jnp.minimum lowering) + pallas minimum
//!   GEMM, MAGMA                   → gemmpallas (same tiling as the mGEMM kernel)
//!   GEMM, cuBLAS                  → gemm (platform-native XLA dot)
//!   GEMM achievable/theoretical   → native optimized/reference CPU GEMM rows
//!
//! Expected shape (paper §6.2): mGEMM within a small factor of GEMM;
//! ternary ≥ intrinsic time; SP ≈ 2× faster than DP.

use std::path::Path;

use comet::config::Precision;
use comet::linalg;
use comet::metrics::counts;
use comet::runtime::ops::BlockOps;
use comet::runtime::PjrtService;
use comet::util::timer::bench_run;
use comet::util::{fmt, Scalar};
use comet::vecdata::{SyntheticKind, VectorSet};

// Bench at the small artifact tier (single-core testbed; the paper used
// n_v = 10,240 × n_f = 12,288 on a K20X).
const NF: usize = 384;
const NV: usize = 128;
const ITERS: usize = 3;

fn run_kind<T: Scalar>(ops: &BlockOps, kind: &str, v: &VectorSet<T>) -> f64 {
    bench_run(kind, 1, ITERS, || {
        std::hint::black_box(ops.mgemm2(kind, v, v).unwrap());
    })
    .median()
}

fn main() {
    let artifacts = Path::new("artifacts");
    assert!(
        artifacts.join("manifest.txt").exists(),
        "run `make artifacts` first"
    );
    let svc = PjrtService::start(artifacts).unwrap();

    let v32: VectorSet<f32> = VectorSet::generate(SyntheticKind::RandomGrid, 1, NF, NV, 0);
    let v64: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 1, NF, NV, 0);
    let ops32 = BlockOps::new(svc.client(), Precision::F32);
    let ops64 = BlockOps::new(svc.client(), Precision::F64);

    println!("Table 1 — kernel times (n_f = {NF}, n_v = {NV}, {ITERS} iters, median)");
    println!("paper: K20X GPU via modified MAGMA; here: PJRT CPU via AOT artifacts\n");

    let rows: &[(&str, &str)] = &[
        ("mGEMM, ternary (XLA select)", "mgemm2ternary"),
        ("mGEMM, min intrinsic (XLA minimum)", "mgemm2"),
        ("mGEMM, Pallas kernel ternary", "mgemm2pallasternary"),
        ("mGEMM, Pallas kernel minimum", "mgemm2pallas"),
        ("GEMM, Pallas same-tiling (≈MAGMA)", "gemmpallas"),
        ("GEMM, XLA dot (≈cuBLAS)", "gemm"),
    ];
    let gops = counts::ops_mgemm_block(NF, NV, NV) as f64 / 1e9;

    let mut table = fmt::Table::new(&["kernel", "single (s)", "SP Gop/s", "double (s)", "DP Gop/s"]);
    let mut gemm_sp = 0.0;
    let mut mgemm_sp = 0.0;
    for (label, kind) in rows {
        let t32 = run_kind(&ops32, kind, &v32);
        let t64 = run_kind(&ops64, kind, &v64);
        if *kind == "gemm" {
            gemm_sp = t32;
        }
        if *kind == "mgemm2" {
            mgemm_sp = t32;
        }
        table.row(&[
            label.to_string(),
            format!("{t32:.4}"),
            format!("{:.2}", gops / t32),
            format!("{t64:.4}"),
            format!("{:.2}", gops / t64),
        ]);
    }

    // Native comparator rows (the paper's "achievable peak" analogues).
    let t_nat32 = bench_run("native-opt-gemm-sp", 1, ITERS, || {
        std::hint::black_box(linalg::optimized::gemm(&v32, &v32));
    })
    .median();
    let t_nat64 = bench_run("native-opt-gemm-dp", 1, ITERS, || {
        std::hint::black_box(linalg::optimized::gemm(&v64, &v64));
    })
    .median();
    table.row(&[
        "GEMM, native optimized (host roof proxy)".into(),
        format!("{t_nat32:.4}"),
        format!("{:.2}", gops / t_nat32),
        format!("{t_nat64:.4}"),
        format!("{:.2}", gops / t_nat64),
    ]);
    let t_natm32 = bench_run("native-opt-mgemm-sp", 1, ITERS, || {
        std::hint::black_box(linalg::optimized::mgemm2(&v32, &v32));
    })
    .median();
    let t_natm64 = bench_run("native-opt-mgemm-dp", 1, ITERS, || {
        std::hint::black_box(linalg::optimized::mgemm2(&v64, &v64));
    })
    .median();
    table.row(&[
        "mGEMM, native optimized".into(),
        format!("{t_natm32:.4}"),
        format!("{:.2}", gops / t_natm32),
        format!("{t_natm64:.4}"),
        format!("{:.2}", gops / t_natm64),
    ]);
    table.print();

    if gemm_sp > 0.0 && mgemm_sp > 0.0 {
        println!(
            "\nmGEMM/GEMM SP time ratio: {:.2}× (paper Table 1: 2.602/1.035 ≈ 2.5× vs cuBLAS,\n\
             1.24× vs the MAGMA GEMM it was derived from)",
            mgemm_sp / gemm_sp
        );
    }
}
