//! Ablation — §6.3 model fidelity: predicted vs measured step times for
//! the 2-way pipeline on this testbed, plus the model's tuning-advice
//! directions (larger blocks ⇒ higher mGEMM fraction; fewer stages ⇒
//! higher 3-way efficiency).

use comet::comm::cost::CostModel;
use comet::config::{BackendKind, InputSource, Precision, RunConfig};
use comet::coordinator::run;
use comet::decomp::Grid;
use comet::perfmodel::{self, ModelInput};
use comet::util::fmt;
use comet::vecdata::SyntheticKind;

/// Host cost model: in-process channels are ~free; measure an effective
/// bandwidth from one exchange-heavy run.
fn host_net() -> CostModel {
    CostModel { latency_s: 2e-6, bandwidth_bps: 2.0e9 }
}

fn measured_total(nvp: usize, nf: usize, npv: usize) -> (f64, f64) {
    let cfg = RunConfig {
        num_way: 2,
        nv: nvp * npv,
        nf,
        precision: Precision::F64,
        backend: BackendKind::CpuOptimized,
        grid: Grid::new(1, npv, 1),
        input: InputSource::Synthetic { kind: SyntheticKind::RandomGrid, seed: 3 },
        store_metrics: false,
        ..Default::default()
    };
    let out = run(&cfg).unwrap();
    // Per-virtual-node compute second (shared core ⇒ divide by np).
    (out.stats.t_total / npv as f64, out.stats.t_compute)
}

fn main() {
    println!("Ablation — §6.3 performance model vs measurement (2-way, DP, native backend)\n");

    // Calibrate t_gemm from a single-node run.
    let nf = 384;
    let nvp = 192;
    let (t_single, _) = measured_total(nvp, nf, 1);
    // npv=1: one diagonal block, served by the triangular kernel
    // (~0.5 effective full blocks).
    let blocks_single = 0.5;
    let t_gemm = t_single / blocks_single;

    let mut table = fmt::Table::new(&["npv", "load ℓ", "predicted/node", "measured/node", "ratio"]);
    for npv in [2usize, 3, 4, 6] {
        let load = comet::decomp::two_way::blocks_per_node(npv, 1, 0, 0);
        let m = ModelInput {
            nfp: nf,
            nvp,
            elem_bytes: 8,
            t_gemm,
            t_cpu: 0.1 * t_gemm,
            load,
            diag_load: 1, // every node owns its Δ=0 diagonal block
            threads: 1,
            // t_gemm is calibrated from the already-vectorized kernel,
            // so no extra lane speedup applies.
            lane_width: 1,
            t_spawn: 0.0,
            pool_warm: true,
            triangular: true,
            nst: 1,
            reload_frac: 0.0,
            disk_bw: 2e9,
            prefetch: true,
            retry_rate: 0.0,
            t_backoff: 0.0,
            ckpt_frac: 0.0,
            ckpt_bw: 0.0,
            ingest_bytes: 0,
            ingest_bw: 0.0,
            net: host_net(),
            link: host_net(),
        };
        let pred = perfmodel::predict_2way(&m).total;
        let (meas, _) = measured_total(nvp, nf, npv);
        table.row(&[
            npv.to_string(),
            load.to_string(),
            fmt::secs(pred),
            fmt::secs(meas),
            format!("{:.2}", meas / pred),
        ]);
    }
    table.print();
    println!("\nexpect ratio ≈ 1 within a small factor — the model is a step-count ×");
    println!("kernel-time estimate, and ℓ grows with npv at npr=1 (paper §6.3).");

    // Tuning-advice directions.
    println!("\nmodel advice sweeps (§6.3 guidance):");
    let base = ModelInput {
        nfp: 5000,
        nvp: 10_240,
        elem_bytes: 8,
        t_gemm: 6.5,
        t_cpu: 0.1,
        load: 13,
        diag_load: 0,
        threads: 1,
        lane_width: 1,
        t_spawn: 0.0,
        pool_warm: true,
        triangular: false,
        nst: 16,
        reload_frac: 0.0,
        disk_bw: 2e9,
        prefetch: true,
        retry_rate: 0.0,
        t_backoff: 0.0,
        ckpt_frac: 0.0,
        ckpt_bw: 0.0,
        ingest_bytes: 0,
        ingest_bw: 0.0,
        net: CostModel::gemini(),
        link: CostModel::pcie2(),
    };
    let mut t2 = fmt::Table::new(&["knob", "setting", "mGEMM fraction"]);
    for load in [1usize, 4, 13] {
        let m = ModelInput { load, ..base };
        t2.row(&[
            "load ℓ".into(),
            load.to_string(),
            format!("{:.1}%", 100.0 * perfmodel::predict_2way(&m).gemm_fraction()),
        ]);
    }
    for nst in [1usize, 16, 240] {
        let m = ModelInput { nvp: 2880, t_gemm: 0.5, load: 6, nst, ..base };
        t2.row(&[
            "stages n_st (3-way)".into(),
            nst.to_string(),
            format!("{:.1}%", 100.0 * perfmodel::predict_3way(&m).gemm_fraction()),
        ]);
    }
    t2.print();
    println!("\nexpect: fraction rises with ℓ, falls with n_st — the paper's 'maximize ℓ,");
    println!("minimize n_st subject to memory' tuning rule.");

    let (npv, npr, nst) = perfmodel::advise(32, 200_000, 6 << 30, 8, 2);
    println!("\nadvise(np=32, nv=200k, 6 GB, DP, 2-way) -> npv={npv} npr={npr} nst={nst}");
}
