//! Perf-trajectory bench harness: times every native kernel family
//! (full + triangular, across thread counts) and appends a run to
//! `BENCH_kernels.json` at the repository root.
//!
//!   cargo bench --bench bench_kernels            # full sizes
//!   cargo bench --bench bench_kernels -- --quick # CI smoke sizes
//!   cargo bench --bench bench_kernels -- --fresh # overwrite the file
//!
//! ## `BENCH_kernels.json` schema (`comet-bench-kernels/v1`)
//!
//! ```json
//! {
//!   "schema": "comet-bench-kernels/v1",
//!   "unit": "elementwise comparisons per second",
//!   "runs": [
//!     {
//!       "created_unix": 1700000000,
//!       "quick": false,
//!       "source": "measured",
//!       "entries": [
//!         { "metric": "czekanowski", "repr": "float", "kernel": "full",
//!           "threads": 1, "nf": 512, "nv": 256, "iters": 3,
//!           "secs_median": 0.0123, "comparisons_per_sec": 2.7e9 }
//!       ]
//!     }
//!   ]
//! }
//! ```
//!
//! * `runs` is append-only: each harness invocation adds one run object
//!   (oldest first), so the file accumulates a perf trajectory across
//!   PRs. `--fresh` restarts it.
//! * `comparisons_per_sec` is the paper's Table 1 figure of merit: one
//!   elementwise comparison per feature of each computed output entry
//!   (`linalg::opcount::{ops_full, ops_tri}` / median seconds).
//! * `kernel` is "full" (square block), "tri" (symmetry-halved
//!   diagonal block), or a whole-campaign session point:
//!   "session-oneshot" (fresh `coordinator::run` per request —
//!   re-ingest every time) vs "session-reused" (one `session::Session`
//!   serving every request from its ingest-once block cache) vs
//!   "session-ooc" (the reused campaign under a block budget that
//!   forces a spill-store round trip every run — the out-of-core
//!   steady state) vs "session-faulted" (the reused campaign with
//!   scripted link drops injected into every run, each recovered by a
//!   checksum-verified retransmit — the fault-recovery steady state).
//!   For the session points `comparisons_per_sec` is
//!   campaign comparisons
//!   (nf · nv(nv−1)/2 per run × runs) over the median batch time, and
//!   `iters` is the number of back-to-back runs per batch.
//!   "ingest-bed" is the real-data front door: one PLINK `.bed`
//!   column-span decode plus the two-plane CCC pack, rated in genotype
//!   calls (nf · nv) per second rather than pair comparisons.
//! * `repr` matches the metric's block representation
//!   ("float" | "packed" | "packed2").
//! * `source` is "measured" for harness output; seed points generated
//!   without a local toolchain are marked "estimate" and are replaced
//!   in spirit by the first measured run appended after them.

use std::path::PathBuf;
use std::sync::Arc;

use comet::config::{InputSource, RunConfig};
use comet::coordinator::{self, run_streamed_opts, BlockProvider, RunOpts};
use comet::decomp::Grid;
use comet::linalg::{opcount, optimized, sorenson};
use comet::metrics::MetricId;
use comet::output::sink::DiscardSink;
use comet::session::{Session, SessionLimits};
use comet::testkit::faults::{scripted_comm_plan, FaultKind};
use comet::util::timer::bench_run;
use comet::vecdata::bits::BitVectorSet;
use comet::vecdata::{SyntheticKind, VectorSet};

const THREADS: [usize; 3] = [1, 2, 4];

struct Entry {
    metric: &'static str,
    repr: &'static str,
    kernel: &'static str,
    threads: usize,
    nf: usize,
    nv: usize,
    iters: usize,
    secs: f64,
    cps: f64,
}

fn time_kernel(label: &str, iters: usize, ops: u64, mut f: impl FnMut()) -> (f64, f64) {
    let secs = bench_run(label, 1, iters, || {
        f();
    })
    .median();
    (secs, ops as f64 / secs)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick");
    let fresh = args.iter().any(|a| a == "--fresh");
    let (nf, nv, iters) = if quick { (96, 64, 2) } else { (512, 256, 3) };

    let grid: VectorSet<f64> = VectorSet::generate(SyntheticKind::RandomGrid, 1, nf, nv, 0);
    let alleles: VectorSet<f64> = VectorSet::generate(SyntheticKind::Alleles, 1, nf, nv, 0);
    let bits = BitVectorSet::generate(1, nf, nv, 0.4);

    let full_ops = opcount::ops_full(nf, nv, nv);
    let tri_ops = opcount::ops_tri(nf, nv);
    let mut entries: Vec<Entry> = Vec::new();

    // Warm the persistent pool up front so every timed point reflects
    // the steady state — dispatch to parked workers, zero spawns in the
    // timed region (spawn cost is once-per-process, not per call).
    comet::linalg::pool::warm(*THREADS.iter().max().unwrap());

    for threads in THREADS {
        let mut push = |metric, repr, kernel, secs: f64, cps: f64| {
            entries.push(Entry { metric, repr, kernel, threads, nf, nv, iters, secs, cps });
        };
        let (s, c) = time_kernel("czekanowski-full", iters, full_ops, || {
            std::hint::black_box(optimized::mgemm2_mt(&grid, &grid, threads));
        });
        push("czekanowski", "float", "full", s, c);
        let (s, c) = time_kernel("czekanowski-tri", iters, tri_ops, || {
            std::hint::black_box(optimized::mgemm2_tri_mt(&grid, threads));
        });
        push("czekanowski", "float", "tri", s, c);
        let (s, c) = time_kernel("ccc-full", iters, full_ops, || {
            std::hint::black_box(optimized::gemm_mt(&alleles, &alleles, threads));
        });
        push("ccc", "float", "full", s, c);
        let (s, c) = time_kernel("ccc-tri", iters, tri_ops, || {
            std::hint::black_box(optimized::gemm_tri_mt(&alleles, threads));
        });
        push("ccc", "float", "tri", s, c);
        let (s, c) = time_kernel("sorenson-full", iters, full_ops, || {
            std::hint::black_box(sorenson::sorenson_mgemm_mt(&bits, &bits, threads));
        });
        push("sorenson", "packed", "full", s, c);
        let (s, c) = time_kernel("sorenson-tri", iters, tri_ops, || {
            std::hint::black_box(sorenson::sorenson_mgemm_tri_mt(&bits, threads));
        });
        push("sorenson", "packed", "tri", s, c);
    }

    // --- Session amortization: the same multi-node Sorensen campaign
    // run back-to-back, one-shot (fresh load + pack per run) vs through
    // one reused Session (blocks ingested once, then served from the
    // dataset cache). One warmup batch each, so the reused point times
    // pure cache-hit runs — the long-lived-server steady state.
    {
        let runs = if quick { 4usize } else { 8 };
        let cfg = RunConfig {
            metric: MetricId::Sorenson,
            nv,
            nf,
            grid: Grid::new(1, 2, 1),
            input: InputSource::Synthetic { kind: SyntheticKind::RandomGrid, seed: 1 },
            store_metrics: false,
            ..Default::default()
        };
        let campaign_cmps = nf as u64 * (nv as u64 * (nv as u64 - 1) / 2) * runs as u64;
        let oneshot = bench_run("session-oneshot", 1, iters, || {
            for _ in 0..runs {
                std::hint::black_box(coordinator::run(&cfg).unwrap());
            }
        })
        .median();
        let session = Session::new();
        let req = session.request_from_config(&cfg).unwrap();
        let reused = bench_run("session-reused", 1, iters, || {
            for _ in 0..runs {
                std::hint::black_box(session.run(&req, &DiscardSink).unwrap());
            }
        })
        .median();
        entries.push(Entry {
            metric: "sorenson",
            repr: "packed",
            kernel: "session-oneshot",
            threads: 1,
            nf,
            nv,
            iters: runs,
            secs: oneshot,
            cps: campaign_cmps as f64 / oneshot,
        });
        entries.push(Entry {
            metric: "sorenson",
            repr: "packed",
            kernel: "session-reused",
            threads: 1,
            nf,
            nv,
            iters: runs,
            secs: reused,
            cps: campaign_cmps as f64 / reused,
        });

        // Out-of-core point: the same campaign through a session whose
        // block budget holds ~1.5 of the dataset's two blocks, so every
        // run cycles one block through the spill store (encode + spill
        // on eviction, checksum-verified reload on the next touch). The
        // gap to "session-reused" is the streaming-ingest overhead in
        // the spill-bound steady state.
        let resident = session.cache_stats().bytes;
        let ooc_session = Session::with_limits(
            "artifacts",
            SessionLimits { block_cache_bytes: Some(resident * 3 / 4), ..Default::default() },
        );
        let ooc_req = ooc_session.request_from_config(&cfg).unwrap();
        let ooc = bench_run("session-ooc", 1, iters, || {
            for _ in 0..runs {
                std::hint::black_box(ooc_session.run(&ooc_req, &DiscardSink).unwrap());
            }
        })
        .median();
        let stats = ooc_session.cache_stats();
        assert!(stats.spills >= 1 && stats.reloads >= 1, "session-ooc point must spill+reload");
        entries.push(Entry {
            metric: "sorenson",
            repr: "packed",
            kernel: "session-ooc",
            threads: 1,
            nf,
            nv,
            iters: runs,
            secs: ooc,
            cps: campaign_cmps as f64 / ooc,
        });

        // Fault-recovery point: the same campaign served from the
        // session's already-ingested blocks, with two PRNG-placed link
        // drops scripted into every run (np=2 ranks × 2 send ops
        // each). Every drop costs one checksum-verified retransmit
        // plus one retry-policy backoff sleep, so the gap to
        // "session-reused" prices the comm fault-recovery machinery in
        // its steady state — and checksums stay bit-identical to the
        // clean campaign by contract.
        let clean = session.run(&req, &DiscardSink).unwrap().checksum;
        let provider = Arc::new(req.dataset().clone()) as Arc<dyn BlockProvider>;
        let faulted = bench_run("session-faulted", 1, iters, || {
            for r in 0..runs {
                let plan = scripted_comm_plan(100 + r as u64, 2, 2, 2, FaultKind::Drop);
                let opts = RunOpts { faults: Some(plan), ..Default::default() };
                let p = Arc::clone(&provider);
                let out = run_streamed_opts(&cfg, None, p, &DiscardSink, &opts).unwrap();
                assert!(out.stats.comm_retries >= 1, "faulted point must retransmit");
                assert_eq!(out.checksum, clean, "fault recovery must stay bit-identical");
            }
        })
        .median();
        entries.push(Entry {
            metric: "sorenson",
            repr: "packed",
            kernel: "session-faulted",
            threads: 1,
            nf,
            nv,
            iters: runs,
            secs: faulted,
            cps: campaign_cmps as f64 / faulted,
        });
    }

    // --- Real-data ingest point: one PLINK .bed column-span decode
    // plus the two-plane CCC pack — the per-node-block price a
    // .bed-fed run pays once at ingest (the kernels then consume the
    // packed planes directly). Rated in genotype calls per pass, not
    // pair comparisons.
    {
        let dir = std::env::temp_dir().join(format!("comet-bench-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let bed = comet::vecdata::geno::write_plink_fixture(&dir, "bench", &alleles).unwrap();
        let calls = (nf * nv) as u64;
        let (s, c) = time_kernel("ingest-bed", iters, calls, || {
            let span = comet::vecdata::geno::read_bed_cols(&bed, nf, nv, 0, nv).unwrap();
            std::hint::black_box(span.pack2());
        });
        entries.push(Entry {
            metric: "ccc",
            repr: "packed2",
            kernel: "ingest-bed",
            threads: 1,
            nf,
            nv,
            iters,
            secs: s,
            cps: c,
        });
        std::fs::remove_dir_all(&dir).ok();
    }

    println!(
        "bench_kernels: nf={nf} nv={nv} iters={iters}{}",
        if quick { " (quick)" } else { "" }
    );
    println!("{:<14} {:<7} {:<6} {:>7} {:>12} {:>16}", "metric", "repr", "kernel", "threads", "median (s)", "cmp/s");
    for e in &entries {
        println!(
            "{:<14} {:<7} {:<6} {:>7} {:>12.6} {:>16.3e}",
            e.metric, e.repr, e.kernel, e.threads, e.secs, e.cps
        );
    }

    let run_json = render_run(&entries, quick);
    let path = bench_file();
    write_trajectory(&path, &run_json, fresh);
    println!("\nappended run to {}", path.display());
}

fn bench_file() -> PathBuf {
    // rust/ is a workspace member; the trajectory lives at the repo root.
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("workspace root")
        .join("BENCH_kernels.json")
}

fn render_run(entries: &[Entry], quick: bool) -> String {
    let created = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut s = String::new();
    s.push_str("    {\n");
    s.push_str(&format!("      \"created_unix\": {created},\n"));
    s.push_str(&format!("      \"quick\": {quick},\n"));
    s.push_str("      \"source\": \"measured\",\n");
    s.push_str("      \"entries\": [\n");
    for (i, e) in entries.iter().enumerate() {
        s.push_str(&format!(
            "        {{ \"metric\": \"{}\", \"repr\": \"{}\", \"kernel\": \"{}\", \"threads\": {}, \
             \"nf\": {}, \"nv\": {}, \"iters\": {}, \"secs_median\": {:.9}, \
             \"comparisons_per_sec\": {:.6e} }}{}\n",
            e.metric,
            e.repr,
            e.kernel,
            e.threads,
            e.nf,
            e.nv,
            e.iters,
            e.secs,
            e.cps,
            if i + 1 < entries.len() { "," } else { "" }
        ));
    }
    s.push_str("      ]\n");
    s.push_str("    }");
    s
}

/// Append `run_json` to the trajectory file (creating it if absent or
/// unrecognized). The writer controls the exact layout, so appending is
/// a suffix splice at the closing `]` of "runs".
fn write_trajectory(path: &std::path::Path, run_json: &str, fresh: bool) {
    const SCHEMA: &str = "comet-bench-kernels/v1";
    const TAIL: &str = "\n  ]\n}\n";
    let header = format!(
        "{{\n  \"schema\": \"{SCHEMA}\",\n  \"unit\": \"elementwise comparisons per second\",\n  \"runs\": [\n"
    );
    let existing = if fresh { None } else { std::fs::read_to_string(path).ok() };
    let text = match existing {
        Some(t) if t.contains(SCHEMA) && t.ends_with(TAIL) => {
            format!("{},\n{}{}", &t[..t.len() - TAIL.len()], run_json, TAIL)
        }
        Some(old) => {
            // Unrecognized layout (hand-edited, CRLF checkout, …):
            // never destroy the accumulated trajectory silently — park
            // it next to the fresh file.
            let bak = path.with_extension("json.bak");
            std::fs::write(&bak, old).expect("back up BENCH_kernels.json");
            eprintln!(
                "bench_kernels: {} is not in splice format; backed it up to {} and restarted the trajectory",
                path.display(),
                bak.display()
            );
            format!("{header}{run_json}{TAIL}")
        }
        None => format!("{header}{run_json}{TAIL}"),
    };
    std::fs::write(path, text).expect("write BENCH_kernels.json");
}
