//! Figures 7 + 8 and Table 3 — 2-way weak scaling, double and single
//! precision: time-to-solution and per-node operation/comparison rates
//! as node count grows with fixed per-node work.
//!
//! Paper: n_vp = 10,240 (DP) / 12,288 (SP) vectors/node, load ℓ = 13,
//! up to 17,472 nodes; per-node rate loses only 37–41% over three
//! orders of magnitude; maxima in Table 3 (1.70 / 4.29 Pcmp/s).
//!
//! Here each virtual node's compute is *measured* (shared core), and
//! the per-node rate series — the paper's right-hand graphs — is the
//! reproduction target: it should stay flat as npv grows.

use comet::config::{BackendKind, InputSource, Precision, RunConfig};
use comet::coordinator::run_with_client;
use comet::decomp::{two_way, Grid};
use comet::metrics::counts;
use comet::runtime::RuntimeClient;
use comet::util::fmt;
use comet::vecdata::SyntheticKind;

fn series(client: &RuntimeClient, precision: Precision, nvp: usize, nf: usize, load: usize) -> (f64, f64) {
    println!(
        "— {} weak scaling: {nvp} vectors/node, n_f = {nf}, target load ℓ = {load}",
        precision.tag()
    );
    // Shared physical core ⇒ the weak-scaling flatness target is the
    // AGGREGATE rate (flat aggregate ⇔ flat per-node rate on real
    // hardware — the paper's right-hand graphs).
    let mut table = fmt::Table::new(&[
        "npv", "npr", "np", "nv", "time", "agg Gop/s", "agg 2×Gcmp/s", "agg Gcmp/s",
    ]);
    let mut max_cmp_rate_total = 0.0f64;
    let mut max_ops_rate_total = 0.0f64;
    for npv in [1usize, 2, 3, 4, 6, 8] {
        let npr = two_way::npr_for_load(npv, load).min(3); // cap: shared core
        let np = npv * npr;
        let nv = nvp * npv;
        let cfg = RunConfig {
            num_way: 2,
            nv,
            nf,
            precision,
            backend: BackendKind::Pjrt,
            grid: Grid::new(1, npv, npr),
            input: InputSource::Synthetic { kind: SyntheticKind::RandomGrid, seed: 8 },
            store_metrics: false,
            ..Default::default()
        };
        let out = run_with_client(&cfg, Some(client.clone())).unwrap();
        let cmps = counts::cmp_2way(nf, nv) as f64;
        let ops = (counts::ops_2way_numerators(nf, nv) + counts::ops_2way_denominators(nf, nv)) as f64;
        let cmp_rate = cmps / out.stats.t_total;
        let ops_rate = ops / out.stats.t_total;
        max_cmp_rate_total = max_cmp_rate_total.max(cmp_rate);
        max_ops_rate_total = max_ops_rate_total.max(ops_rate);
        table.row(&[
            npv.to_string(),
            npr.to_string(),
            np.to_string(),
            nv.to_string(),
            fmt::secs(out.stats.t_total),
            format!("{:.3}", ops_rate / 1e9),
            format!("{:.3}", 2.0 * cmp_rate / 1e9),
            format!("{:.3}", cmp_rate / 1e9),
        ]);
    }
    table.print();
    println!();
    (max_ops_rate_total, max_cmp_rate_total)
}

fn main() {
    assert!(
        std::path::Path::new("artifacts/manifest.txt").exists(),
        "run `make artifacts` first"
    );
    println!("Figures 7/8 — 2-way weak scaling (PJRT backend, virtual nodes share one core)\n");
    // One service for the whole sweep: executables compile once (§Perf).
    let svc = comet::runtime::PjrtService::start(std::path::Path::new("artifacts")).unwrap();
    let client = svc.client();
    // Scaled: 128 vectors/node (paper: 10,240/12,288), small-tier depth.
    let (ops_dp, cmp_dp) = series(&client, Precision::F64, 128, 384, 3);
    let (ops_sp, cmp_sp) = series(&client, Precision::F32, 128, 384, 3);

    println!("Table 3 — maximum aggregate performance (this testbed):");
    let mut t = fmt::Table::new(&["method", "operations/s", "comparisons/s"]);
    t.row(&["double precision".into(), fmt::rate(ops_dp), fmt::cmp_rate(cmp_dp)]);
    t.row(&["single precision".into(), fmt::rate(ops_sp), fmt::cmp_rate(cmp_sp)]);
    t.print();
    println!("\npaper Table 3: 3.40e15 op/s / 1.70e15 cmp/s (DP), 8.59e15 / 4.29e15 (SP)");
    println!("expected shape here: ops ≈ 2× comparisons per row; SP faster than DP;");
    println!("aggregate rate roughly flat down the npv column (weak scaling on a");
    println!("shared core: flat aggregate ⇔ the paper's flat per-node rate).");
}
