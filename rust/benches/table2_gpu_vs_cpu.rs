//! Table 2 — accelerated vs. CPU runtime, 2-way and 3-way.
//!
//! Paper: GPU 41× (2-way) and 27× (3-way) over a reasonable (not
//! maximally optimized) CPU implementation, on 32 nodes. Here the
//! "GPU" is the PJRT/XLA artifact path and the "CPU" the naive
//! reference implementation; we also show the optimized-CPU middle row
//! for calibration. Expected shape: accelerated ≫ reference, ratio in
//! double digits; 3-way ratio lower than 2-way (as in the paper).

use std::path::Path;

use comet::config::{BackendKind, InputSource, Precision, RunConfig};
use comet::coordinator::run_with_client;
use comet::decomp::Grid;
use comet::runtime::{PjrtService, RuntimeClient};
use comet::util::fmt;
use comet::vecdata::SyntheticKind;

fn time_run(cfg: &RunConfig, client: &RuntimeClient) -> f64 {
    let need = matches!(cfg.backend, BackendKind::Pjrt);
    let out = run_with_client(cfg, need.then(|| client.clone())).unwrap();
    out.stats.t_total
}

fn main() {
    assert!(
        Path::new("artifacts/manifest.txt").exists(),
        "run `make artifacts` first"
    );
    // Paper: 20,000 fields, 200,000 (2-way) / 6,144 (3-way) vectors on
    // 32 nodes, DP. Scaled: 1,536 fields, 1,024 / 256 vectors on 4
    // virtual nodes (blocks land exactly on artifact tiers — §Perf).
    let svc = PjrtService::start(Path::new("artifacts")).unwrap();
    let client = svc.client();
    let base = RunConfig {
        precision: Precision::F64,
        grid: Grid::new(1, 4, 1),
        input: InputSource::Synthetic { kind: SyntheticKind::RandomGrid, seed: 2 },
        store_metrics: false,
        ..Default::default()
    };
    let cfg2 = RunConfig { num_way: 2, nv: 1024, nf: 1536, ..base.clone() };
    let cfg3 = RunConfig { num_way: 3, nv: 256, nf: 1536, ..base.clone() };

    println!("Table 2 — accelerated (PJRT) vs CPU runtimes, double precision");
    println!("paper setting: 32 Titan nodes; here: 4 virtual nodes, scaled sizes\n");
    let mut table = fmt::Table::new(&["num way", "pjrt (s)", "cpu-opt (s)", "cpu-ref (s)", "ratio ref/pjrt"]);
    for (way, cfg) in [(2usize, cfg2), (3usize, cfg3)] {
        let mut c = cfg.clone();
        c.backend = BackendKind::Pjrt;
        let t_pjrt = time_run(&c, &client);
        c.backend = BackendKind::CpuOptimized;
        let t_opt = time_run(&c, &client);
        c.backend = BackendKind::CpuReference;
        let t_ref = time_run(&c, &client);
        table.row(&[
            way.to_string(),
            format!("{t_pjrt:.3}"),
            format!("{t_opt:.3}"),
            format!("{t_ref:.3}"),
            format!("{:.1}", t_ref / t_pjrt),
        ]);
    }
    table.print();
    println!("\npaper Table 2 ratios: 41.0 (2-way), 27.1 (3-way) — GPU vs modestly-optimized CPU.");
    println!("Here all engines share one core, so the ratio reflects XLA's fused/vectorized");
    println!("lowering vs a scalar loop — the same 'optimized kernel vs plain code' axis,");
    println!("without the device-parallelism component.");
}
