//! Figures 9 + 10 and Table 4 — 3-way weak scaling, double and single
//! precision, staged pipeline (paper: n_vp = 2,880 vectors/node,
//! final stage of n_st = 16, load ℓ = 6, up to 18,424 nodes;
//! rate > 300 GOps/node sustained; Table 4 maxima 2.44 / 5.70 Pcmp/s).

use comet::config::{BackendKind, InputSource, Precision, RunConfig};
use comet::coordinator::run_with_client;
use comet::decomp::{three_way, Grid};
use comet::metrics::{counts, indexing};
use comet::runtime::RuntimeClient;
use comet::util::fmt;
use comet::vecdata::SyntheticKind;

fn series(client: &RuntimeClient, precision: Precision, nvp: usize, nf: usize, nst: usize) -> (f64, f64) {
    println!(
        "— {} 3-way weak scaling: {nvp} vectors/node, n_f = {nf}, final stage of n_st = {nst}",
        precision.tag()
    );
    // Shared core ⇒ report aggregate rates (flat = ideal weak scaling;
    // see fig7 bench).
    let mut table = fmt::Table::new(&[
        "npv", "npr", "np", "nv", "time", "agg Gop/s", "agg 2×Gcmp/s", "agg Gcmp/s",
    ]);
    let mut max_cmp = 0.0f64;
    let mut max_ops = 0.0f64;
    for npv in [1usize, 2, 3, 4] {
        let npr = three_way::npr_for_load(npv, ((npv + 1) * (npv + 2)).div_ceil(2)).min(2);
        let np = npv * npr;
        let nv = nvp * npv;
        let cfg = RunConfig {
            num_way: 3,
            nv,
            nf,
            precision,
            backend: BackendKind::Pjrt,
            grid: Grid::new(1, npv, npr),
            num_stage: nst,
            stage: Some(nst - 1), // the paper computes the final stage
            input: InputSource::Synthetic { kind: SyntheticKind::RandomGrid, seed: 12 },
            store_metrics: false,
            ..Default::default()
        };
        let out = run_with_client(&cfg, Some(client.clone())).unwrap();
        // Rates use the comparisons actually computed this stage.
        let frac = out.stats.metrics as f64 / indexing::num_triples(nv) as f64;
        let cmps = counts::cmp_3way(nf, nv) as f64 * frac;
        let ops = counts::ops_3way_total(nf, nv) as f64 * frac;
        let cmp_rate = cmps / out.stats.t_total;
        let ops_rate = ops / out.stats.t_total;
        max_cmp = max_cmp.max(cmp_rate);
        max_ops = max_ops.max(ops_rate);
        table.row(&[
            npv.to_string(),
            npr.to_string(),
            np.to_string(),
            nv.to_string(),
            fmt::secs(out.stats.t_total),
            format!("{:.3}", ops_rate / 1e9),
            format!("{:.3}", 2.0 * cmp_rate / 1e9),
            format!("{:.3}", cmp_rate / 1e9),
        ]);
    }
    table.print();
    println!();
    (max_ops, max_cmp)
}

fn main() {
    assert!(
        std::path::Path::new("artifacts/manifest.txt").exists(),
        "run `make artifacts` first"
    );
    println!("Figures 9/10 — 3-way weak scaling (PJRT backend, staged; virtual nodes share one core)\n");
    let svc = comet::runtime::PjrtService::start(std::path::Path::new("artifacts")).unwrap();
    let client = svc.client();
    // Scaled: 64 vectors/node (paper: 2,880; 64 = the exact s-tier edge,
    // no padding waste — §Perf), final stage of 4.
    let (ops_dp, cmp_dp) = series(&client, Precision::F64, 64, 384, 4);
    let (ops_sp, cmp_sp) = series(&client, Precision::F32, 64, 384, 4);

    println!("Table 4 — maximum aggregate performance (this testbed):");
    let mut t = fmt::Table::new(&["method", "operations/s", "comparisons/s"]);
    t.row(&["double precision".into(), fmt::rate(ops_dp), fmt::cmp_rate(cmp_dp)]);
    t.row(&["single precision".into(), fmt::rate(ops_sp), fmt::cmp_rate(cmp_sp)]);
    t.print();
    println!("\npaper Table 4: 5.75e15 op/s / 2.44e15 cmp/s (DP), 13.40e15 / 5.70e15 (SP)");
    println!("expected shape: SP ≈ 2× DP; 3-way op/cmp ratio ≈ 2.4 (2-way startup included);");
    println!("per-node rate flattening as npv grows (volume blocks dominate).");
}
