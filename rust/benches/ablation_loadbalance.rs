//! Ablation — Figure 2(a–c): naive upper-triangular block assignment vs
//! the paper's block-circulant schedule.
//!
//! The paper's claim: the naive plan leaves block rows with up to 2×
//! the average work (Figure 2(b)); the circulant plan equalizes rows
//! exactly (Figure 2(c)) — worth "the potential 2X-6X performance loss
//! factor" of the title claim's redundancy/imbalance elimination.

use comet::decomp::two_way;
use comet::util::fmt;

fn main() {
    println!("Ablation — 2-way load balance: naive (Fig 2a) vs block-circulant (Fig 2c)\n");
    let mut table = fmt::Table::new(&[
        "npv", "naive min..max", "naive makespan/ideal", "circulant min..max", "circulant makespan/ideal",
    ]);
    for npv in [4usize, 8, 16, 32, 64] {
        let naive: Vec<usize> = (0..npv).map(|pv| two_way::plan_naive(npv, pv).len()).collect();
        let circ: Vec<usize> = (0..npv)
            .map(|pv| two_way::blocks_per_node(npv, 1, pv, 0))
            .collect();
        let ideal = |v: &[usize]| v.iter().sum::<usize>() as f64 / v.len() as f64;
        let makespan = |v: &[usize]| *v.iter().max().unwrap() as f64;
        table.row(&[
            npv.to_string(),
            format!("{}..{}", naive.iter().min().unwrap(), naive.iter().max().unwrap()),
            format!("{:.2}×", makespan(&naive) / ideal(&naive)),
            format!("{}..{}", circ.iter().min().unwrap(), circ.iter().max().unwrap()),
            format!("{:.2}×", makespan(&circ) / ideal(&circ)),
        ]);
    }
    table.print();
    println!("\nexpected: naive →(≈2.0× makespan inflation as npv grows); circulant ≈1.0×.");

    // 3-way: volume-combo ownership balance across slabs.
    println!("\n3-way volume-combo ownership balance (circular-canonical rule):");
    let mut t3 = fmt::Table::new(&["npv", "combos/slab min..max", "slices/slab (paper (npv+1)(npv+2))"]);
    for npv in [4usize, 6, 8, 12] {
        use comet::decomp::three_way;
        let counts: Vec<usize> = (0..npv)
            .map(|pv| three_way::combos_owned(npv, pv).len())
            .collect();
        let slices: Vec<usize> = (0..npv).map(|pv| three_way::slices_per_slab(npv, pv)).collect();
        t3.row(&[
            npv.to_string(),
            format!("{}..{}", counts.iter().min().unwrap(), counts.iter().max().unwrap()),
            format!(
                "{}..{} (paper {})",
                slices.iter().min().unwrap(),
                slices.iter().max().unwrap(),
                (npv + 1) * (npv + 2)
            ),
        ]);
    }
    t3.print();
}
