//! Table 5 — the realistic PheWAS sample problem with unoptimized I/O:
//! input read, metrics computation, and output write timed separately;
//! short real vector length (n_f = 385) vs a long-vector control.
//!
//! Paper: n_v = 189,625 × n_f = 385 poplar SNP/metabolite profiles, SP;
//! rate/node 125e9 cmp/s at n_f = 385 vs 415e9 at n_f = 20,000 (2-way)
//! — the short-depth mGEMM runs below peak. Expected shape here: the
//! long-n_f control shows a clearly higher per-node comparison rate.

use std::path::Path;

use comet::config::{BackendKind, InputSource, Precision, RunConfig};
use comet::coordinator::run_with_client;
use comet::decomp::Grid;
use comet::metrics::{counts, indexing};
use comet::util::fmt;
use comet::vecdata::{io as vio, SyntheticKind, VectorSet};

fn main() {
    assert!(
        Path::new("artifacts/manifest.txt").exists(),
        "run `make artifacts` first"
    );
    let dir = std::env::temp_dir().join(format!("comet-table5-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let svc = comet::runtime::PjrtService::start(Path::new("artifacts")).unwrap();
    let client = svc.client();

    // Scaled: 2,048 vectors (paper: 189,625); n_f = 385 real shape and a
    // 1,536-deep control (paper control: 20,000).
    // Block sizes land on artifact-tier edges (nvb = 512 → 2×2 tiles of
    // 256; nvb = 64 exact) so padding doesn't distort the n_f comparison.
    let nv = 2048;
    let nv3 = 256;
    println!("Table 5 — sample problem timings (unoptimized I/O), single precision\n");
    let mut table = fmt::Table::new(&[
        "num way", "n_f", "input (s)", "metrics comp (s)", "output (s)", "cmp rate/node",
    ]);

    for (num_way, nf) in [(2usize, 385usize), (2, 1536), (3, 385), (3, 1536)] {
        let this_nv = if num_way == 2 { nv } else { nv3 };
        // Write the input file (its read is the timed "input" phase).
        let input_path = dir.join(format!("in_{num_way}_{nf}.bin"));
        let set: VectorSet<f32> =
            VectorSet::generate(SyntheticKind::PhewasLike, 77, nf, this_nv, 0);
        vio::write_raw(&input_path, &set).unwrap();

        let cfg = RunConfig {
            num_way,
            nv: this_nv,
            nf,
            precision: Precision::F32,
            backend: BackendKind::Pjrt,
            grid: Grid::new(1, 4, 1),
            num_stage: if num_way == 3 { 4 } else { 1 },
            stage: if num_way == 3 { Some(3) } else { None },
            input: InputSource::File { path: input_path.to_string_lossy().into_owned() },
            store_metrics: false,
            output_dir: (num_way == 2)
                .then(|| dir.join(format!("out_{nf}")).to_string_lossy().into_owned()),
            ..Default::default()
        };
        let out = run_with_client(&cfg, Some(client.clone())).unwrap();
        let np = cfg.grid.np() as f64;
        let (cmps, frac) = if num_way == 2 {
            (counts::cmp_2way(nf, this_nv) as f64, 1.0)
        } else {
            let f = out.stats.metrics as f64 / indexing::num_triples(this_nv) as f64;
            (counts::cmp_3way(nf, this_nv) as f64 * f, f)
        };
        let _ = frac;
        table.row(&[
            num_way.to_string(),
            nf.to_string(),
            format!("{:.3}", out.stats.t_input),
            format!("{:.3}", out.stats.t_compute),
            if num_way == 2 { format!("{:.3}", out.stats.t_output) } else { "-".into() },
            fmt::cmp_rate(cmps / out.stats.t_total / np),
        ]);
    }
    table.print();
    println!("\npaper Table 5 rates/node: 125e9 (n_f=385) vs 415e9 (n_f=20k) 2-way;");
    println!("54e9 vs 321e9 3-way — longer vectors lift mGEMM efficiency. The same");
    println!("short-vs-long ordering should appear in the rate column above.");
    std::fs::remove_dir_all(&dir).ok();
}
