//! Table 6 — comparison with related-work method *classes*, normalized
//! against hardware capability.
//!
//! The paper compares absolute cmp/s across codes (GBOOST, GWISFI,
//! Haque 1-bit, epiSNP, CoMet…) and a normalized performance ratio
//! (cmp/s per peak FLOP/s). Those codes are not portable here; we
//! reimplement the method *classes* on this host so the normalized
//! comparison is apples-to-apples:
//!   · 1-bit popcount similarity (Haque-style)        — bit-packed AND+popcount
//!   · 2-bit/3-bit GWAS contingency codes (GBOOST-ish) — 2-bit packed genotype AND
//!   · float Proportional Similarity (CoMet — ours)    — PJRT mGEMM + native
//!
//! Expected shape (paper §6.9): bitwise codes win absolute cmp/s by a
//! wide margin (≥10× — elements are 1–2 bits, not 32), while the float
//! method's normalized ratio is competitive.

use std::path::Path;

use comet::config::Precision;
use comet::linalg::{optimized, sorenson};
use comet::runtime::ops::BlockOps;
use comet::runtime::PjrtService;
use comet::util::timer::bench_run;
use comet::util::fmt;
use comet::vecdata::bits::BitVectorSet;
use comet::vecdata::{SyntheticKind, VectorSet};

/// 2-bit genotype code baseline (GBOOST-class): each SNP is {0,1,2}
/// packed 2 bits/entry; pair "comparison" = popcount of genotype-match
/// planes — the same AND+popcount inner loop GBOOST runs per
/// contingency cell.
fn genotype_pairs(words: &[Vec<u64>], nv: usize) -> u64 {
    let mut acc = 0u64;
    for i in 0..nv {
        for j in (i + 1)..nv {
            let (a, b) = (&words[i], &words[j]);
            let mut c = 0u64;
            for (x, y) in a.iter().zip(b) {
                // genotype equality per 2-bit lane: xnor both bits.
                let eq = !(x ^ y);
                let lane = eq & (eq >> 1) & 0x5555_5555_5555_5555;
                c += lane.count_ones() as u64;
            }
            acc += c;
        }
    }
    acc
}

fn main() {
    assert!(
        Path::new("artifacts/manifest.txt").exists(),
        "run `make artifacts` first"
    );
    let nf = 1536;
    let nv = 192;
    let pairs = (nv * (nv - 1) / 2) as f64;
    let cmps = nf as f64 * pairs;

    println!("Table 6 — method classes on one host core, {nv} vectors × {nf} elements\n");
    let mut table = fmt::Table::new(&["code class", "element", "time", "cmp/s", "norm vs float-mGEMM"]);

    // 1-bit Haque-class popcount.
    let bits = BitVectorSet::generate(3, nf, nv, 0.3);
    let t_bits = bench_run("1bit", 1, 3, || {
        std::hint::black_box(sorenson::sorenson_all_pairs(&bits).len());
    })
    .median();

    // 2-bit GBOOST-class genotype code.
    let words_per = nf.div_ceil(32);
    let geno: Vec<Vec<u64>> = (0..nv)
        .map(|v| {
            let mut s = comet::util::prng::Stream::for_vector(5, v as u64);
            (0..words_per).map(|_| s.next_u64() & 0xAAAA_AAAA_AAAA_AAAA ^ s.next_u64()).collect()
        })
        .collect();
    let t_geno = bench_run("2bit", 1, 3, || {
        std::hint::black_box(genotype_pairs(&geno, nv));
    })
    .median();

    // Float Proportional Similarity — native optimized (CoMet CPU class).
    let v32: VectorSet<f32> = VectorSet::generate(SyntheticKind::RandomGrid, 7, nf, nv, 0);
    let t_native = bench_run("float-native", 1, 3, || {
        std::hint::black_box(optimized::mgemm2(&v32, &v32).data.len());
    })
    .median();

    // Float Proportional Similarity — PJRT artifact (CoMet GPU class).
    let svc = PjrtService::start(Path::new("artifacts")).unwrap();
    let ops = BlockOps::new(svc.client(), Precision::F32);
    let t_pjrt = bench_run("float-pjrt", 1, 3, || {
        std::hint::black_box(ops.mgemm2("mgemm2", &v32, &v32).unwrap().data.len());
    })
    .median();

    let base_rate = cmps / t_native;
    for (label, elem, t) in [
        ("1-bit popcount (Haque-class)", "1 bit", t_bits),
        ("2-bit genotype AND (GBOOST-class)", "2 bit", t_geno),
        ("float PS, native mGEMM (CoMet CPU)", "f32", t_native),
        ("float PS, PJRT mGEMM (CoMet accel)", "f32", t_pjrt),
    ] {
        let rate = cmps / t;
        table.row(&[
            label.into(),
            elem.into(),
            fmt::secs(t),
            fmt::cmp_rate(rate),
            format!("{:.2}", rate / base_rate),
        ]);
    }
    table.print();
    println!("\npaper Table 6 shape: 1–2-bit codes reach ~10×+ the float method's raw");
    println!("cmp/s (element is 1/32nd the size), while CoMet's normalized ratio stays");
    println!("within the field's range — check the same ordering above.");
}
